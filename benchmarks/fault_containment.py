"""Fault-containment benchmark: a crash-looping background lock holder
must not hurt time-sensitive tail latency (DESIGN.md section 12).

Two sim runs of the same mixed workload (time-sensitive lock users +
background analytics) on the UFS policy:

* ``baseline``  -- fault-free;
* ``crashloop`` -- plus one background job that repeatedly acquires the
  shared lock, burns CPU, and crashes while holding it.  Its
  `RetryPolicy` restarts it after every panic, so it crash-loops for the
  whole horizon (or until quarantine if ``--retries`` is finite).

Reported per run: TS latency stats, fault counters, and -- for the
crash-loop run -- the **containment latency** distribution: virtual time
from each ``panic`` trace event to the next time-sensitive
``lock_acquire`` on the contested lock, i.e. how quickly the force-release
path returns the lock to foreground work.

    PYTHONPATH=src python -m benchmarks.fault_containment [--short]
        [--out fault_containment.json]

Prints ``name,value`` CSV rows; ``--out`` writes the full JSON.
"""
from __future__ import annotations

import argparse
import json
import random

from repro.core import (Job, RetryPolicy, SchedKernel, SchedTracer, Tier,
                        make_policy, percentile)
from repro.core.faults import crashing_holder
from repro.core.task import (AcquireLock, Block, Burst, ReleaseLock,
                             RequestBegin, RequestEnd)

THINK = 0.3e-3
TS_CS = 0.2e-3          # TS critical section (short, paper-style OLTP)
BG_QUERY = 0.05         # background analytics burst
HOLD_CPU = 2e-3         # crasher's CPU while holding the lock


def ts_locker(seed: int, lock):
    """Closed-loop TS worker whose transaction needs the shared lock."""
    rng = random.Random(seed)
    while True:
        yield Block(rng.expovariate(1.0 / THINK))
        yield RequestBegin()
        yield AcquireLock(lock)
        yield Burst(TS_CS)
        yield ReleaseLock(lock)
        yield RequestEnd()


def bg_analytics(seed: int):
    rng = random.Random(seed)
    while True:
        yield RequestBegin()
        yield Burst(BG_QUERY * rng.uniform(0.95, 1.05))
        yield RequestEnd()


def run_once(horizon: float, crash: bool, retries: int) -> dict:
    tracer = SchedTracer(capacity=1 << 20)
    k = SchedKernel(2, make_policy("ufs"), tracer=tracer)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("shared")

    ts_jids = []
    for i in range(4):
        j = Job(ts, behavior=ts_locker(i, lock), name=f"ts{i}", kind="bursty")
        ts_jids.append(j.jid)
        k.add_job(j)
    for i in range(2):
        k.add_job(Job(bg, behavior=bg_analytics(100 + i), name=f"bg{i}",
                      kind="bound"))
    if crash:
        # backoff_growth=1.0: constant 1 ms restart delay, a steady crash
        # loop instead of an exponentially self-silencing one.
        k.add_job(Job(bg, behavior_factory=crashing_holder(
                          lock, hold_cpu=HOLD_CPU),
                      name="crashy", kind="bound",
                      retry_policy=RetryPolicy(max_retries=retries,
                                               backoff=1e-3,
                                               backoff_growth=1.0)))
    m = k.run(horizon, warmup=0.2)

    out = {
        "ts_latency": m.latency_stats("ts"),
        "ts_completed": m.completed["ts"],
        "panics": len(m.panics),
        "retries": m.retries,
        "quarantines": m.quarantines,
    }
    if crash:
        # panic -> next TS lock_acquire on the contested lock
        ts_set = set(ts_jids)
        deltas, pending = [], None
        for e in tracer.events:
            if e.kind == "panic":
                pending = e.t if pending is None else pending
            elif (pending is not None and e.kind == "lock_acquire"
                  and e.jid in ts_set):
                deltas.append(e.t - pending)
                pending = None
        out["containment"] = {
            "n": len(deltas),
            "p50_ms": percentile(deltas, 50) * 1e3 if deltas else None,
            "p99_ms": percentile(deltas, 99) * 1e3 if deltas else None,
            "max_ms": max(deltas) * 1e3 if deltas else None,
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--short", action="store_true", help="CI-sized horizon")
    ap.add_argument("--horizon", type=float, default=None)
    ap.add_argument("--retries", type=int, default=1_000_000,
                    help="crasher retry budget (default: never quarantines)")
    ap.add_argument("--out", default=None, help="write full JSON here")
    args = ap.parse_args()
    horizon = args.horizon or (4.0 if args.short else 20.0)

    results = {
        "horizon_s": horizon,
        "baseline": run_once(horizon, crash=False, retries=0),
        "crashloop": run_once(horizon, crash=True, retries=args.retries),
    }
    base, fault = results["baseline"], results["crashloop"]
    for name, r in (("baseline", base), ("crashloop", fault)):
        lat = r["ts_latency"]
        print(f"{name}.ts_p50_ms,{lat['p50'] * 1e3:.3f}")
        print(f"{name}.ts_p99_ms,{lat['p99'] * 1e3:.3f}")
        print(f"{name}.ts_completed,{r['ts_completed']}")
    print(f"crashloop.panics,{fault['panics']}")
    print(f"crashloop.retries,{fault['retries']}")
    cont = fault["containment"]
    if cont["n"]:
        print(f"crashloop.containment_p50_ms,{cont['p50_ms']:.3f}")
        print(f"crashloop.containment_p99_ms,{cont['p99_ms']:.3f}")
    # the headline: TS p99 under a crash-looping BG holder vs fault-free
    ratio = fault["ts_latency"]["p99"] / base["ts_latency"]["p99"]
    results["ts_p99_ratio"] = ratio
    print(f"ts_p99_ratio,{ratio:.3f}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
