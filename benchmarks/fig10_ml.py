"""Figure 10 / section 6.8: in-database ML background workload.

Real JAX work: the background jobs train a logistic-regression model
(MADlib ``logregr_train`` analogue) in live mode, while the time-sensitive
bursty class serves interactive requests -- on the live scheduler with real
threads and real compute, not simulated service times.

On this single-core container the live run is a functional demonstration
(one slot); the quantitative mixed-workload bands are covered by the sim
benchmarks. We report iterations/s for the ML job and request latency for
the bursty class under MIN:MAX.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Tier, build_kernel
from repro.core.live import LiveJob


def _logreg_trainer():
    """Returns a chunk fn running one GD iteration per chunk."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096, 64))
    true_w = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    y = (x @ true_w > 0).astype(jnp.float32)
    w = jnp.zeros((64,))

    @jax.jit
    def step(w):
        def loss(w):
            p = jax.nn.sigmoid(x @ w)
            return -jnp.mean(y * jnp.log(p + 1e-7) + (1 - y) * jnp.log(1 - p + 1e-7))
        g = jax.grad(loss)(w)
        return w - 0.1 * g
    state = {"w": w, "iters": 0}

    def chunk(budget):
        t0 = time.monotonic()
        while time.monotonic() - t0 < budget:
            state["w"] = step(state["w"])
            state["w"].block_until_ready()
            state["iters"] += 1
        return "yield"
    return chunk, state


def _bursty_client(reqs: list):
    """Short JAX matmul burst + think; records latency per request."""
    a = jnp.ones((128, 128))

    @jax.jit
    def work(a):
        return (a @ a).sum()

    def chunk(budget):
        t0 = time.monotonic()
        work(a).block_until_ready()
        reqs.append(time.monotonic() - t0)
        time.sleep(0.002)                  # client think
        return "yield"
    return chunk


def run(short=False):
    rows = []
    dur = 2.0 if short else 5.0
    for pol in ("vdf", "ufs"):
        kernel = build_kernel("live", policy=pol, n_slots=1)
        ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
        bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
        ml_chunk, ml_state = _logreg_trainer()
        reqs: list = []
        kernel.start()
        kernel.wake(LiveJob(bg, ml_chunk, name="logreg", kind="bound"))
        kernel.wake(LiveJob(ts, _bursty_client(reqs), name="client", kind="bursty"))
        time.sleep(dur)
        kernel.stop()
        iters = ml_state["iters"] / dur
        lat = float(np.mean(reqs) * 1e3) if reqs else float("nan")
        rows.append((f"fig10.{pol}.logreg_iters_s", dur * 1e6, f"{iters:.0f}"))
        rows.append((f"fig10.{pol}.bursty_lat_ms", dur * 1e6, f"{lat:.2f}"))
        rows.append((f"fig10.{pol}.bursty_reqs", dur * 1e6, f"{len(reqs)}"))
    return rows
