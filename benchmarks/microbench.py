"""Microbenchmark harness for the sim scheduling core (BENCH_*.json).

Measures raw simulator throughput -- the hard ceiling on how large a
workload the repro can study (ROADMAP: "run as fast as the hardware
allows") -- across the three policy families and three queue-depth scales:

* ``shallow`` -- the paper's own MIN:MAX shape (8 slots, 8+8 workers);
* ``mixed``   -- oversubscribed mixed tiers (8 slots, 64 bursty + 512 bound);
* ``deep``    -- the deep-queue stress: >= 1k queued jobs per slot plus
  lock-churn driving the hint boost/unboost path, so the per-event cost of
  keyed queue removal, run-end cancellation, and trace overhead dominates.

For each (policy, scale) the sim horizon is split into chunks; each chunk
contributes one wall-time-per-event sample, giving a p50/p99 "dispatch
cost" distribution alongside total events/sec, plus clock-heap and
DSQ-occupancy high-water marks.

Output schema (``BENCH_8.json``, stable field names -- future PRs append
``BENCH_<n>.json`` files to form a trajectory)::

    {
      "schema": "repro.microbench/v1",
      "short": bool,               # CI mode (shorter horizons, smaller deep scale)
      "calib_us": float,           # fixed pure-Python loop wall time: the
                                   # regression gate scales baseline ev/s by
                                   # calib ratio, so a slower CI machine is
                                   # not mistaken for a code regression
      "results": [{
        "name": "ufs.deep",        # <policy>.<scale>
        "policy": "ufs", "scale": "deep",
        "n_slots": int, "horizon": float,
        "events": int,             # clock events processed in the measured span
        "wall_s": float,
        "events_per_sec": float,   # events / wall_s  (the regression-gated figure)
        "dispatch_us": {"p50": float, "p99": float, "mean": float},
        "clock": {"max_live": int, "max_raw": int},   # event-heap occupancy
        "queues": {"max_local": int, "max_group": int},
        "summary_sha256": "...",   # sha256 of Metrics.summary() JSON: must be
      }, ...]                      # machine-independent (sim is deterministic)
    }

Regression gating (used by CI)::

    python -m benchmarks.microbench --short --out BENCH_8.json \
        --baseline BENCH_8.json --max-regression 0.30

compares ``events_per_sec`` per result name against the committed baseline
and exits non-zero if any benchmark regressed by more than the threshold.
``summary_sha256`` values are compared exactly when the baseline was
produced at the same scale settings (same ``short`` flag): the sim is
deterministic, so any drift is a behaviour change, not noise.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import random
import sys
import time
from typing import Iterator, Optional

from repro.core import Job, Tier, build_kernel
from repro.core.metrics import percentile
from repro.core.task import AcquireLock, Block, Burst, ReleaseLock
from repro.core.workloads import bound_worker, bursty_worker

POLICIES = ("ufs", "vdf", "fifo")
SCALES = ("shallow", "mixed", "deep")
CHUNKS = 50

HOLD_CPU = 0.4e-3     # lock hold burst (background holder)
USE_CPU = 0.1e-3      # lock use burst (time-sensitive waiter)
THINK = 0.5e-3        # waiter think time between acquisitions


# ---------------------------------------------------------------------------
# Lock-churn workloads (the Table-4 inversion micro-experiment, looped):
# each waiter acquisition while the background holder owns the lock fires a
# hint boost, which must *remove* the holder from a deep group DSQ -- the
# keyed-removal hot path.
# ---------------------------------------------------------------------------

def _churn_holder(lock) -> Iterator:
    while True:
        yield AcquireLock(lock)
        yield Burst(HOLD_CPU)
        yield ReleaseLock(lock)


def _churn_waiter(lock, seed: int) -> Iterator:
    rng = random.Random(seed)
    while True:
        yield Block(rng.uniform(0.5 * THINK, 1.5 * THINK))
        yield AcquireLock(lock)
        yield Burst(USE_CPU)
        yield ReleaseLock(lock)


# ---------------------------------------------------------------------------
# Scenario builders
# ---------------------------------------------------------------------------

def _add_jobs(kernel, group, n, mk_behavior, kind, prefix):
    for i in range(n):
        kernel.add_job(Job(group, behavior=mk_behavior(i),
                           name=f"{prefix}-{i}", kind=kind))


def build_scenario(policy: str, scale: str, short: bool):
    """Returns (kernel, n_slots, horizon, warmup)."""
    if scale == "shallow":
        n_slots, horizon = 8, (0.8 if short else 2.0)
        k = build_kernel("sim", policy=policy, n_slots=n_slots)
        ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000.0)
        bg = k.create_group("bg", Tier.BACKGROUND, 1.0)
        _add_jobs(k, ts, 8, bursty_worker, "bursty", "ts")
        _add_jobs(k, bg, 8,
                  lambda i: bound_worker(100 + i, query_cpu=0.05), "bound", "bg")
    elif scale == "mixed":
        n_slots, horizon = 8, (0.6 if short else 1.5)
        k = build_kernel("sim", policy=policy, n_slots=n_slots)
        ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000.0)
        bg = k.create_group("bg", Tier.BACKGROUND, 1.0)
        _add_jobs(k, ts, 64, bursty_worker, "bursty", "ts")
        _add_jobs(k, bg, 512,
                  lambda i: bound_worker(1000 + i, query_cpu=0.05), "bound", "bg")
    elif scale == "deep":
        # >= 1k queued jobs per slot: a saturating background backlog that
        # every boost must remove from, plus 8 lock-churn pairs driving the
        # boost/unboost path and a light TS foreground keeping wakes alive.
        n_slots = 2
        n_bg = 2048 if short else 8192
        horizon = 0.5 if short else 1.0
        k = build_kernel("sim", policy=policy, n_slots=n_slots)
        ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000.0)
        bg = k.create_group("bg", Tier.BACKGROUND, 1.0)
        _add_jobs(k, ts, 4, bursty_worker, "bursty", "ts")
        _add_jobs(k, bg, n_bg,
                  lambda i: bound_worker(2000 + i, query_cpu=0.05), "bound", "bg")
        for p in range(8):
            lock = k.create_lock(f"churn{p}")
            k.add_job(Job(bg, behavior=_churn_holder(lock),
                          name=f"holder-{p}", kind="holder"))
            k.add_job(Job(ts, behavior=_churn_waiter(lock, 9000 + p),
                          name=f"waiter-{p}", kind="waiter"))
    else:
        raise ValueError(f"unknown scale {scale!r}")
    warmup = 0.1 * horizon
    return k, n_slots, horizon, warmup


# ---------------------------------------------------------------------------
# Instrumentation helpers (tolerant of cores without the counters)
# ---------------------------------------------------------------------------

def _events_processed(clock) -> int:
    return getattr(clock, "processed", 0)


def _clock_occupancy(clock) -> tuple:
    raw = getattr(clock, "heap_size", None)
    if raw is None:
        raw = len(getattr(clock, "_heap", ()))
    try:
        live = len(clock)
    except TypeError:
        live = raw
    return live, raw


def _queue_occupancy(kernel) -> tuple:
    max_local = max((len(s.local_dsq) for s in kernel.slots), default=0)
    max_group = max((len(g.dsq) for g in kernel.groups.values()
                     if getattr(g, "dsq", None) is not None), default=0)
    return max_local, max_group


# ---------------------------------------------------------------------------
# One benchmark run
# ---------------------------------------------------------------------------

def bench_one(policy: str, scale: str, short: bool, chunks: int = CHUNKS) -> dict:
    kernel, n_slots, horizon, warmup = build_scenario(policy, scale, short)
    clock = kernel.clock
    kernel.metrics.window_start = warmup
    kernel.metrics.window_end = horizon
    clock.run_until(warmup)                      # admit everything; fill queues

    samples = []
    max_live = max_raw = max_local = max_group = 0
    e_start = _events_processed(clock)
    t_start = time.perf_counter()
    for c in range(1, chunks + 1):
        target = warmup + (horizon - warmup) * c / chunks
        e0 = _events_processed(clock)
        w0 = time.perf_counter()
        clock.run_until(target)
        dw = time.perf_counter() - w0
        de = _events_processed(clock) - e0
        if de > 0:
            samples.append(dw / de * 1e6)
        live, raw = _clock_occupancy(clock)
        ml, mg = _queue_occupancy(kernel)
        max_live, max_raw = max(max_live, live), max(max_raw, raw)
        max_local, max_group = max(max_local, ml), max(max_group, mg)
    wall = time.perf_counter() - t_start
    events = _events_processed(clock) - e_start
    kernel._settle_accounting()

    summary = kernel.metrics.summary(n_slots=n_slots)
    sha = hashlib.sha256(
        json.dumps(summary, sort_keys=True).encode()).hexdigest()
    return {
        "name": f"{policy}.{scale}",
        "policy": policy, "scale": scale,
        "n_slots": n_slots, "horizon": horizon,
        "events": events, "wall_s": round(wall, 6),
        "events_per_sec": round(events / wall, 1) if wall > 0 else 0.0,
        "dispatch_us": {
            "p50": round(percentile(samples, 50), 3) if samples else None,
            "p99": round(percentile(samples, 99), 3) if samples else None,
            "mean": round(sum(samples) / len(samples), 3) if samples else None,
        },
        "clock": {"max_live": max_live, "max_raw": max_raw},
        "queues": {"max_local": max_local, "max_group": max_group},
        "summary_sha256": sha,
    }


# ---------------------------------------------------------------------------
# Baseline comparison (CI regression gate)
# ---------------------------------------------------------------------------

def _calibration_us() -> float:
    """Wall time of a fixed pure-Python loop (best of 3): a proxy for this
    machine's interpreter speed, so the regression gate compares code, not
    hardware."""
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        x = 0
        for i in range(200_000):
            x += i ^ (x >> 3)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def compare_to_baseline(doc: dict, baseline: dict,
                        max_regression: float) -> list:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures = []
    base_rows = {r["name"]: r for r in baseline.get("results", [])}
    same_settings = baseline.get("short") == doc.get("short")
    # Scale the baseline to this machine: a box half as fast as the one
    # that produced the baseline halves the expected events/sec.
    scale = 1.0
    if baseline.get("calib_us") and doc.get("calib_us"):
        scale = baseline["calib_us"] / doc["calib_us"]
    for row in doc["results"]:
        base = base_rows.get(row["name"])
        if base is None:
            continue
        b, n = base["events_per_sec"] * scale, row["events_per_sec"]
        if b > 0 and n < b * (1.0 - max_regression):
            failures.append(
                f"{row['name']}: events/sec {n:.0f} < "
                f"{(1.0 - max_regression):.2f} * machine-scaled baseline "
                f"{b:.0f}")
        if same_settings and base.get("summary_sha256") != row["summary_sha256"]:
            failures.append(
                f"{row['name']}: Metrics.summary() hash drifted "
                f"({base.get('summary_sha256', '?')[:12]} -> "
                f"{row['summary_sha256'][:12]}) -- determinism break")
    return failures


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_all(short: bool, only: Optional[list] = None) -> dict:
    results = []
    for scale in SCALES:
        for policy in POLICIES:
            name = f"{policy}.{scale}"
            if only and not any(name.startswith(p) or p.startswith(name)
                                or scale.startswith(p) for p in only):
                continue
            row = bench_one(policy, scale, short)
            print(f"{row['name']}: {row['events']} events in "
                  f"{row['wall_s']:.2f}s = {row['events_per_sec']:.0f} ev/s, "
                  f"p50={row['dispatch_us']['p50']}us "
                  f"p99={row['dispatch_us']['p99']}us, "
                  f"clock[live/raw]={row['clock']['max_live']}/"
                  f"{row['clock']['max_raw']}, "
                  f"q[local/group]={row['queues']['max_local']}/"
                  f"{row['queues']['max_group']}", flush=True)
            results.append(row)
    return {"schema": "repro.microbench/v1", "short": short,
            "calib_us": round(_calibration_us(), 2), "results": results}


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--short", action="store_true",
                    help="CI mode: shorter horizons, smaller deep scale")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the JSON document to PATH (e.g. BENCH_8.json)")
    ap.add_argument("--only", default=None,
                    help="comma-separated scenario prefixes (ufs.deep, deep, vdf)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON to gate regressions against")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="fail if events/sec drops more than this fraction "
                         "below baseline (default 0.30)")
    args = ap.parse_args(argv)

    baseline = None
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)

    only = args.only.split(",") if args.only else None
    doc = run_all(args.short, only=only)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out} ({len(doc['results'])} results)")

    if baseline is not None:
        failures = compare_to_baseline(doc, baseline, args.max_regression)
        if failures:
            for fail in failures:
                print(f"REGRESSION: {fail}", file=sys.stderr)
            return 1
        print(f"baseline gate passed "
              f"(max regression {args.max_regression:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
