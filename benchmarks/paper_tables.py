"""One benchmark function per paper table/figure (sim mode, deterministic).

Each returns a list of CSV rows: (name, value, derived-annotation).
"""
from __future__ import annotations

import time

from repro.core import Job, SchedTracer, Tier, build_kernel, slot_busy_from_trace
from repro.core.experiment import scenario, run_mix
from repro.core.workloads import burner, holder, schbench_worker, waiter

from .workloads import DURATION, SCHEDULERS, SLOTS, WARMUP, WORKERS


def _wall(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ------------------------------------------------------------- Fig 1 and 6
def fig1_fig6_mixed_throughput(short=False):
    """Figures 1/6: throughput of CPU-bursty and CPU-bound tasks, SOLO vs
    MIN:MAX vs 50:50, per scheduler."""
    dur = 8.0 if short else DURATION
    rows = []
    for mix in ("solo", "solo_bound", "minmax", "5050"):
        pols = SCHEDULERS if mix in ("minmax", "5050") else ["ufs", "vdf", "rr"]
        if mix == "5050":
            pols = [p for p in pols if p != "idle"]
        for pol in pols:
            r, us = _wall(lambda: scenario(pol, mix, n_slots=SLOTS, n=WORKERS,
                                           duration=dur, warmup=WARMUP))
            ts, bg = r.thr("ts"), r.thr("bg")
            rows.append((f"fig6.{mix}.{pol}.bursty_tx_s", us, f"{ts:.1f}"))
            rows.append((f"fig6.{mix}.{pol}.bound_q_s", us, f"{bg:.2f}"))
    return rows


# ------------------------------------------------------------------ Fig 2
def fig2_placement(short=False):
    """Figure 2: per-slot CPU utilization of the CPU-bursty class under
    MIN:MAX -- EEVDF pile-ups vs UFS even placement."""
    dur = 8.0 if short else DURATION
    rows = []
    for pol in ("vdf", "ufs"):
        # Retain only start/stop events: the Figure-2 reconstruction needs
        # exactly the sched_switch edges, and the filter keeps the ring
        # from wrapping over a full paper-length run.
        tracer = SchedTracer(capacity=1 << 20,
                             kinds={"start_job", "stop_job"})
        r, us = _wall(lambda: scenario(pol, "minmax", n_slots=SLOTS, n=WORKERS,
                                       duration=dur, warmup=WARMUP,
                                       tracer=tracer))
        util = r.metrics.slot_utilization("bursty", SLOTS)
        peak = max(util) or 1.0
        norm = ",".join(f"{100*u/peak:.0f}" for u in util)
        rows.append((f"fig2.{pol}.slot_util_norm", us, norm))
        rows.append((f"fig2.{pol}.skew", us,
                     f"{r.metrics.slot_skew('bursty', SLOTS):.2f}"))
        # The same figure rebuilt from the trace (the paper's method),
        # rather than charge-time accounting: must agree with the row above.
        tutil = slot_busy_from_trace(tracer.events, SLOTS, kind="bursty",
                                     window=(WARMUP, WARMUP + dur),
                                     end=WARMUP + dur)
        tmean = (sum(tutil) / len(tutil)) or 1.0
        rows.append((f"fig2.{pol}.trace_skew", us,
                     f"{max(tutil)/tmean:.2f}"))
    return rows


# ----------------------------------------------------------------- Table 3
def tab3_latency(short=False):
    """Table 3: mean and p95 latency of CPU-bursty tasks."""
    dur = 8.0 if short else DURATION
    rows = []
    for mix in ("solo", "minmax", "5050"):
        for pol in ("vdf", "rr", "ufs"):
            r, us = _wall(lambda: scenario(pol, mix, n_slots=SLOTS, n=WORKERS,
                                           duration=dur, warmup=WARMUP))
            ls = r.lat("ts")
            rows.append((f"tab3.{mix}.{pol}.mean_ms", us, f"{ls['mean']*1e3:.2f}"))
            rows.append((f"tab3.{mix}.{pol}.p95_ms", us, f"{ls['p95']*1e3:.2f}"))
    return rows


# ------------------------------------------------------------------ Fig 7
def fig7_oversubscription(short=False):
    """Figure 7: TS throughput scaling at 8/16/24 bursty workers vs 8
    background workers on 8 slots."""
    dur = 8.0 if short else DURATION
    rows = []
    for n_bursty in (8, 16, 24):
        for pol in ("vdf", "rr", "ufs"):
            r, us = _wall(lambda: run_mix(pol, n_slots=SLOTS, n_bursty=n_bursty,
                                          n_bound=8, duration=dur, warmup=WARMUP))
            rows.append((f"fig7.n{n_bursty}.{pol}.bursty_tx_s", us,
                         f"{r.thr('ts'):.1f}"))
    return rows


# ------------------------------------------------------------------ Fig 8
def fig8_weighted_groups(short=False):
    """Figure 8: 16 CPU-bursty TS workers split into cgroups with weights
    10k : 6.67k plus 16 CPU-bound BG workers split 3 : 2, on 8 slots
    (paper section 6.4). TS proportionality shows in throughput (the tier
    is contention-limited); BG proportionality shows in CPU share (under
    UFS the background tier only receives slack, 'at the cost of
    background tasks')."""
    dur = 8.0 if short else DURATION
    rows = []
    for pol in ("vdf", "ufs"):
        r, us = _wall(lambda: run_mix(
            pol, n_slots=SLOTS, duration=dur, warmup=WARMUP,
            bursty_groups=[("ts_w10k", 10_000.0, 16), ("ts_w6.67k", 6_670.0, 16)],
            bound_groups=[("bg_w3", 3.0, 8), ("bg_w2", 2.0, 8)]))
        for g in ("ts_w10k", "ts_w6.67k"):
            rows.append((f"fig8.{pol}.{g}.tx_s", us, f"{r.thr(g):.1f}"))
        cpu = r.metrics.cpu_by_group
        for g in ("bg_w3", "bg_w2"):
            rows.append((f"fig8.{pol}.{g}.cpu_s", us, f"{cpu[g]:.3f}"))
        ts_ratio = r.thr("ts_w6.67k") / max(r.thr("ts_w10k"), 1e-9)
        bg_ratio = cpu["bg_w2"] / max(cpu["bg_w3"], 1e-9)
        rows.append((f"fig8.{pol}.ts_ratio(expect~0.67)", us, f"{ts_ratio:.2f}"))
        rows.append((f"fig8.{pol}.bg_ratio(expect~0.67)", us, f"{bg_ratio:.2f}"))
    return rows


# ------------------------------------------------------------------ Fig 9
def fig9_schbench(short=False):
    """Figure 9: schbench-analogue general workload -- rps and p99.9 wakeup
    latency, UFS (all tasks background, default weight) vs EEVDF."""
    dur = 8.0 if short else DURATION
    rows = []
    for pol in ("vdf", "ufs"):
        k = build_kernel("sim", policy=pol, n_slots=SLOTS)
        tier = Tier.BACKGROUND if pol == "ufs" else Tier.TIME_SENSITIVE
        g = k.create_group("work", tier, 100.0)
        for i in range(4 * SLOTS):
            k.add_job(Job(g, behavior=schbench_worker(i), kind="schbench"))
        t0 = time.perf_counter()
        m = k.run(WARMUP + dur, warmup=WARMUP)
        us = (time.perf_counter() - t0) * 1e6
        rps = m.throughput("work")
        from repro.core.metrics import percentile
        wake = m.wakeup_latency["work"]
        p999 = percentile(wake, 99.9) * 1e6
        rows.append((f"fig9.{pol}.rps", us, f"{rps:.0f}"))
        rows.append((f"fig9.{pol}.wakeup_p999_us", us, f"{p999:.0f}"))
    return rows


# ----------------------------------------------------------------- Table 4
def tab4_priority_inversion(short=False):
    """Table 4: spinlock holder / waiter / burner micro-experiment."""
    horizon = 200.0 if short else 1500.0
    compute = 1.0 if short else 3.0
    rows = []

    def run(pol, with_burner=True, hints=True, label=None):
        k = build_kernel("sim", policy=pol, hints_enabled=hints)
        ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
        bg = k.create_group("bg", Tier.BACKGROUND, 1)
        lock = k.create_lock("spin")
        h = Job(bg, behavior=holder(lock, compute=compute), name="holder")
        w = Job(ts, behavior=waiter(lock), name="waiter")
        h.pinned_slot = w.pinned_slot = 0
        jobs = [h, w]
        if with_burner:
            b = Job(ts, behavior=burner(), name="burner")
            b.pinned_slot = 0
            jobs.append(b)
        for j in jobs:
            k.add_job(j)
        t0 = time.perf_counter()
        k.run(horizon)
        us = (time.perf_counter() - t0) * 1e6
        name = label or pol
        hl = k.metrics.request_latency.get("bg", [])
        wl = k.metrics.request_latency.get("ts", [])
        wacq = lock.acquired_at.get(w.jid)

        def fmt(v):
            if v is None:
                return "PANIC" if k.metrics.panics else "-"
            return f"{v:.1f}s"
        rows.append((f"tab4.{name}.holder_total", us, fmt(hl[0] if hl else None)))
        rows.append((f"tab4.{name}.waiter_acquire", us, fmt(wacq)))
        rows.append((f"tab4.{name}.waiter_total", us,
                     fmt(wl[0] + 0.1 if wl else None)))

    run("ufs", with_burner=False, label="baseline")
    run("vdf", hints=False, label="eevdf")
    run("fifo", hints=False)
    run("rr", hints=False)
    run("ufs", hints=True)
    run("ufs", hints=False, label="ufs_nohints")
    return rows


# ------------------------------------------------------------ section 6.7
def sec67_hint_overhead(short=False):
    """Section 6.7: hinting enabled vs disabled under MIN:MAX -- <=1%."""
    dur = 8.0 if short else DURATION
    rows = []
    thr = {}
    for hints in (True, False):
        r, us = _wall(lambda: scenario("ufs", "minmax", n_slots=SLOTS,
                                       n=WORKERS, duration=dur, warmup=WARMUP,
                                       hints_enabled=hints))
        thr[hints] = r.thr("ts")
        rows.append((f"sec67.hints_{'on' if hints else 'off'}.tx_s", us,
                     f"{thr[hints]:.1f}"))
    delta = abs(thr[True] - thr[False]) / max(thr[False], 1e-9)
    rows.append(("sec67.overhead_pct", 0.0, f"{100*delta:.2f}"))
    return rows


ALL = [fig1_fig6_mixed_throughput, fig2_placement, tab3_latency,
       fig7_oversubscription, fig8_weighted_groups, fig9_schbench,
       tab4_priority_inversion, sec67_hint_overhead]
