"""Sim/live backend parity: the same UFS policy object driving the same
mixed workload shape through both executors (DESIGN.md section 7).

One slot, one time-sensitive bursty worker against one background bound
worker. Both backends should agree qualitatively: preemptions occur only in
the mixed run (the background job is kicked off the slot when TS work
wakes), never in the solo run, and the TS class holds the larger CPU share
under contention. Sim numbers are deterministic; live numbers come from
real threads so only the ordering is comparable.

Both runs capture a scheduler trace; the TraceSummary counters are diffed
across backends -- the event schema is shared, so any lifecycle kind one
backend emits and the other never does is a parity break (absolute counts
are clock-dependent and never compared).
"""
from __future__ import annotations

import threading
import time

from repro.core import Job, SchedTracer, Tier, build_kernel
from repro.core.live import LiveJob
from repro.core.task import JobState
from repro.core.workloads import bound_worker, bursty_worker


def _sim_run(mixed: bool, dur: float, tracer=None):
    kernel = build_kernel("sim", policy="ufs", n_slots=1, seed=7,
                          tracer=tracer)
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
    kernel.add_job(Job(ts, behavior=bursty_worker(1), name="ts0",
                       kind="bursty"), at=0.0)
    if mixed:
        kernel.add_job(Job(bg, behavior=bound_worker(2, query_cpu=0.05),
                           name="bg0", kind="bound"), at=0.0)
    m = kernel.run(dur)
    return m.preemptions, m.cpu_by_group["ts"], m.cpu_by_group["bg"]


def _live_run(mixed: bool, dur: float, tracer=None):
    kernel = build_kernel("live", policy="ufs", n_slots=1, tracer=tracer)
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)

    def ts_chunk(budget):
        time.sleep(0.002)                  # the transaction burst
        return "blocked"                   # then wait for the next request

    def bg_chunk(budget):
        time.sleep(0.002)                  # one analytics chunk
        return "yield"                     # immediately runnable again

    tsj = LiveJob(ts, ts_chunk, name="ts0", kind="bursty")
    stop = threading.Event()

    def waker():                           # closed-loop client: think 5 ms
        while not stop.is_set():
            time.sleep(0.005)
            if tsj.state == JobState.BLOCKED:
                kernel.wake(tsj)

    kernel.start()
    kernel.wake(tsj)
    if mixed:
        kernel.wake(LiveJob(bg, bg_chunk, name="bg0", kind="bound"))
    wt = threading.Thread(target=waker, daemon=True)
    wt.start()
    time.sleep(dur)
    stop.set()
    wt.join()
    kernel.stop()
    m = kernel.metrics
    return m.preemptions, m.cpu_by_group["ts"], m.cpu_by_group["bg"]


def run(short=False):
    sim_dur = 2.0 if short else 5.0
    live_dur = 0.5 if short else 1.5
    rows = []
    summaries = {}
    for backend, runner, dur in (("sim", _sim_run, sim_dur),
                                 ("live", _live_run, live_dur)):
        tracer = SchedTracer()
        t0 = time.perf_counter()
        p_mixed, ts_cpu, bg_cpu = runner(True, dur, tracer=tracer)
        p_solo, _, _ = runner(False, dur)
        us = (time.perf_counter() - t0) * 1e6
        total = (ts_cpu + bg_cpu) or 1.0
        summaries[backend] = tracer.summary()
        rows.append((f"parity.{backend}.preempt_mixed", us, f"{p_mixed}"))
        rows.append((f"parity.{backend}.preempt_solo", us, f"{p_solo}"))
        rows.append((f"parity.{backend}.ts_share_pct", us,
                     f"{100 * ts_cpu / total:.0f}"))
        rows.append((f"parity.{backend}.trace_events", us,
                     f"{summaries[backend].events}"))
        rows.append((f"parity.{backend}.trace_preempts", us,
                     f"{summaries[backend].counts.get('preempt_slot', 0)}"))
    # Cross-backend schema diff: kinds present in one stream and absent in
    # the other. wake/lock kinds legitimately differ by workload shape;
    # everything else diverging means the backends drifted.
    diff = summaries["sim"].diff(summaries["live"])
    diff.pop("lock_wait", None)
    diff.pop("lock_acquire", None)
    diff.pop("lock_release", None)
    rows.append(("parity.trace.kind_diff", 0,
                 ";".join(sorted(diff)) or "none"))
    return rows
