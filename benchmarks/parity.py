"""Sim/live backend parity: the same UFS policy object driving the same
mixed workload shape through both executors (DESIGN.md section 7).

One slot, one time-sensitive bursty worker against one background bound
worker. Both backends should agree qualitatively: preemptions occur only in
the mixed run (the background job is kicked off the slot when TS work
wakes), never in the solo run, and the TS class holds the larger CPU share
under contention. Sim numbers are deterministic; live numbers come from
real threads so only the ordering is comparable.
"""
from __future__ import annotations

import threading
import time

from repro.core import Job, SchedKernel, Tier, make_policy
from repro.core.live import LiveJob, LiveKernel
from repro.core.task import JobState
from repro.core.workloads import bound_worker, bursty_worker


def _sim_run(mixed: bool, dur: float):
    kernel = SchedKernel(1, make_policy("ufs"), seed=7)
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
    kernel.add_job(Job(ts, behavior=bursty_worker(1), name="ts0",
                       kind="bursty"), at=0.0)
    if mixed:
        kernel.add_job(Job(bg, behavior=bound_worker(2, query_cpu=0.05),
                           name="bg0", kind="bound"), at=0.0)
    m = kernel.run(dur)
    return m.preemptions, m.cpu_by_group["ts"], m.cpu_by_group["bg"]


def _live_run(mixed: bool, dur: float):
    kernel = LiveKernel(1, make_policy("ufs"))
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)

    def ts_chunk(budget):
        time.sleep(0.002)                  # the transaction burst
        return "blocked"                   # then wait for the next request

    def bg_chunk(budget):
        time.sleep(0.002)                  # one analytics chunk
        return "yield"                     # immediately runnable again

    tsj = LiveJob(ts, ts_chunk, name="ts0", kind="bursty")
    stop = threading.Event()

    def waker():                           # closed-loop client: think 5 ms
        while not stop.is_set():
            time.sleep(0.005)
            if tsj.state == JobState.BLOCKED:
                kernel.wake(tsj)

    kernel.start()
    kernel.wake(tsj)
    if mixed:
        kernel.wake(LiveJob(bg, bg_chunk, name="bg0", kind="bound"))
    wt = threading.Thread(target=waker, daemon=True)
    wt.start()
    time.sleep(dur)
    stop.set()
    wt.join()
    kernel.stop()
    m = kernel.metrics
    return m.preemptions, m.cpu_by_group["ts"], m.cpu_by_group["bg"]


def run(short=False):
    sim_dur = 2.0 if short else 5.0
    live_dur = 0.5 if short else 1.5
    rows = []
    for backend, runner, dur in (("sim", _sim_run, sim_dur),
                                 ("live", _live_run, live_dur)):
        t0 = time.perf_counter()
        p_mixed, ts_cpu, bg_cpu = runner(True, dur)
        p_solo, _, _ = runner(False, dur)
        us = (time.perf_counter() - t0) * 1e6
        total = (ts_cpu + bg_cpu) or 1.0
        rows.append((f"parity.{backend}.preempt_mixed", us, f"{p_mixed}"))
        rows.append((f"parity.{backend}.preempt_solo", us, f"{p_solo}"))
        rows.append((f"parity.{backend}.ts_share_pct", us,
                     f"{100 * ts_cpu / total:.0f}"))
    return rows
