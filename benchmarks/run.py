"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
producing run; derived = the paper-comparable metric).

  PYTHONPATH=src python -m benchmarks.run [--short] [--only fig6,tab4,...]
"""
from __future__ import annotations

import argparse
import sys

#: Row-name prefix emitted by each paper_tables benchmark; used to decide
#: whether a --only filter can skip the (expensive) benchmark entirely.
_ROW_PREFIX = {
    "fig1_fig6_mixed_throughput": "fig6",
    "fig2_placement": "fig2",
    "tab3_latency": "tab3",
    "fig7_oversubscription": "fig7",
    "fig8_weighted_groups": "fig8",
    "tab4_priority_inversion": "tab4",
    "fig9_schbench": "fig9",
    "sec67_hint_overhead": "sec67",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--short", action="store_true",
                    help="shorter sim windows (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes (fig6, fig2, tab3, fig7, "
                         "fig8, fig9, tab4, sec67, fig10)")
    ap.add_argument("--skip-live", action="store_true",
                    help="skip the live-JAX fig10 benchmark")
    ap.add_argument("--trace-sample", default=None, metavar="PATH",
                    help="also export a schema-validated Chrome trace of a "
                         "small mixed sim run to PATH (CI artifact)")
    args = ap.parse_args()

    if args.trace_sample:
        from repro.core import trace as trace_mod
        trace_mod.main(["--out", args.trace_sample])

    from . import paper_tables
    # (bench fn, row-name prefix): --only skips non-matching benchmarks
    # *before* running them, not just when printing their rows.
    benches = [(fn, _ROW_PREFIX.get(fn.__name__)) for fn in paper_tables.ALL]
    if not args.skip_live:
        from . import fig10_ml, parity
        benches.append((fig10_ml.run, "fig10"))
        benches.append((parity.run, "parity"))

    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    for fn, prefix in benches:
        # Unknown prefix (new benchmark not yet registered): always run it
        # and let the row-level filter decide.
        if only and prefix is not None and not any(
                p.startswith(prefix) or prefix.startswith(p) for p in only):
            continue
        try:
            rows = fn(short=args.short)
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}", flush=True)
            continue
        for name, us, derived in rows:
            if only and not any(name.startswith(p) for p in only):
                continue
            print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
