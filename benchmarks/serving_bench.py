"""Serving benchmark harness for the live hot path (BENCH_10.json).

Measures end-to-end serving throughput and latency of the live
``InferenceEngine`` + ``ThreadExecutor`` stack under a mixed
interactive+bulk open-loop load -- the workload the paper's scheduling is
*for* -- in two configurations run side by side in one invocation:

* ``baseline`` -- the pre-overhaul path, kept in-tree behind flags:
  ``LiveKernel(dispatch="polling")`` (global condvar, ``notify_all`` herd,
  50 ms idle tick) and ``InferenceEngine(overlap_decode=False,
  batched_admission=False)`` (engine lock held across device compute,
  per-request prefill inside the admission loop);
* ``hotpath`` -- the defaults: per-slot event parking with targeted
  wakeups, snapshot/merge decode outside the lock, batched padded
  admission prefill, one jitted row-publish scatter.

Because both rows land in the same JSON document, the committed
``BENCH_10.json`` *is* the pre-change baseline recording the acceptance
deltas (tokens/sec, p99 worker-wakeup latency, decode-lock hold).

Models: a ``TinyStubModel`` (microsecond steps -- isolates scheduler and
engine overhead) always; the real reduced transformer additionally in full
(non ``--short``) mode.

Output schema (``BENCH_10.json``, stable field names)::

    {
      "schema": "repro.serving_bench/v1",
      "short": bool,
      "calib_us": float,             # same machine-speed proxy as microbench
      "results": [{
        "name": "stub.hotpath",      # <model>.<mode>
        "model": "stub", "mode": "hotpath",
        "n_slots": int, "max_batch": int, "duration_s": float,
        "requests": {"submitted": int, "completed": int, "failed": int},
        "tokens": int,
        "tokens_per_sec": float,     # the regression-gated figure
        "ttft_ms": {"p50": float, "p99": float},        # interactive tier
        "bulk_ttft_ms": {"p50": float, "p99": float},   # background tier
        "itl_ms": {"p50": float, "p99": float},
        "lock_hold_us": {"p50": float, "p99": float, "max": float},
        "wakeup_us": {"p50": float, "p99": float, "n": int},  # probe phase
        "engine": {...},             # EngineStats.summary()
      }, ...],
      "speedup": {"stub": {"tokens_per_sec": x, "wakeup_p99": x}, ...}
    }

Regression gating (used by CI)::

    python -m benchmarks.serving_bench --short --out BENCH_10.short.json \
        --baseline BENCH_10.json --max-regression 0.50

compares ``tokens_per_sec`` per result name against the committed baseline
scaled by the calibration ratio.  Live timing is noisier than the sim, so
the default threshold is looser than microbench's.
"""
from __future__ import annotations

import argparse
import gc
import json
import sys
import time
from typing import Optional

import numpy as np

from repro.core.live import LiveJob, LiveKernel
from repro.core.metrics import percentile
from repro.core.policies import make_policy
from repro.core.task import Tier
from repro.core.trace import SchedTracer, wakeup_delays
from repro.serving.engine import EngineStats, InferenceEngine, Request
from repro.serving.stub import TinyStubModel

MODES = {
    # mode -> (kernel dispatch, overlap_decode, batched_admission)
    "baseline": ("polling", False, False),
    "hotpath": ("event", True, True),
}
# A serving-realistic worker fleet: the dispatch designs differ in how
# wakeups scale with fleet size (polling: notify_all wakes every idle
# worker for a full dispatch scan on every guard exit; event: exactly the
# kicked slot), so the fleet must be big enough for that to show.
N_SLOTS = 48
MAX_BATCH = 8
INTERACTIVE_GAP_S = 0.002      # open-loop interactive arrival gap
BULK_EVERY = 5                 # every Nth submission is a background bulk


def _build_real_model():
    import jax

    from repro.configs import get_arch
    from repro.models.transformer import Model

    cfg = get_arch("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def _build(model_name: str, mode: str):
    dispatch, overlap, batched = MODES[mode]
    if model_name == "stub":
        model = TinyStubModel()
        params = model.init_params(0)
        max_len = 128
    else:
        model, params = _build_real_model()
        max_len = 96
    # Retain only the wakeup-analysis kinds: at serving rates the full
    # stream would wrap a reasonable ring long before the run ends.
    tracer = SchedTracer(capacity=1 << 18,
                         kinds={"wake", "start_job", "park", "unpark"})
    kernel = LiveKernel(N_SLOTS, make_policy("ufs"), tracer=tracer,
                        dispatch=dispatch)
    # The paper's setting is a multi-tenant box: several workload groups
    # share one fleet.  Idle groups still get walked by every dispatch
    # scan, which is exactly why futile scans (a notify_all herd waking
    # the whole fleet to find nothing) are not free at realistic scale.
    for i in range(6):
        kernel.create_group(f"tenant{i}", Tier.BACKGROUND, 100.0)
    engine = InferenceEngine(model, params, kernel,
                             max_batch=MAX_BATCH, max_len=max_len,
                             overlap_decode=overlap,
                             batched_admission=batched)
    return kernel, engine, tracer, max_len


def _mk_request(i: int, rng: np.random.Generator, vocab: int,
                interactive_tokens: int, bulk_tokens: int) -> Request:
    if i % BULK_EVERY == BULK_EVERY - 1:
        return Request(prompt=rng.integers(1, vocab, 24).astype(np.int32),
                       tier="background", max_new_tokens=bulk_tokens)
    return Request(prompt=rng.integers(1, vocab, 12).astype(np.int32),
                   max_new_tokens=interactive_tokens)


def bench_one(model_name: str, mode: str, duration_s: float) -> dict:
    kernel, engine, tracer, _ = _build(model_name, mode)
    vocab = getattr(engine.model, "vocab", 32)
    interactive_tokens = 8 if model_name == "stub" else 4
    bulk_tokens = 4 if model_name == "stub" else 2
    rng = np.random.default_rng(0)
    kernel.start()
    engine.start()

    # Warmup: compile every jit bucket (admission, decode, bulk, scatter)
    # and settle the worker fleet before the measured window opens.
    warm = [engine.submit(_mk_request(i, rng, vocab, interactive_tokens,
                                      bulk_tokens))
            for i in range(2 * BULK_EVERY)]
    for r in warm:
        r.done_event.wait(timeout=120)
    engine.stats = EngineStats()         # drop warmup samples

    reqs = []
    t_start = time.monotonic()
    trace_t0 = kernel.executor.now
    deadline = t_start + duration_s
    i = 0
    while time.monotonic() < deadline:
        reqs.append(engine.submit(_mk_request(i, rng, vocab,
                                              interactive_tokens,
                                              bulk_tokens)))
        i += 1
        time.sleep(INTERACTIVE_GAP_S)
    for r in reqs:
        r.done_event.wait(timeout=120)
    t_end = time.monotonic()
    trace_t1 = kernel.executor.now
    stats = engine.stats.summary()

    # --- wakeup-latency probe (closed loop) ----------------------------
    # Under the sustained open-loop load the decode loop almost never
    # parks, so the load window yields few wake->start edges.  Probe
    # explicitly: let the engine drain so the loop parks, then each
    # single submit must wake it -- that wake->start_job delay IS the
    # worker wakeup latency (notify_all herd + lock convoy in polling
    # mode vs. a targeted per-slot event in event mode).
    # One sleepy background job keeps the executor guard mildly active
    # during the probe (~600 chunk epilogues/s, GIL released while it
    # sleeps).  In polling mode every epilogue is a notify_all broadcast:
    # all idle workers wake, re-acquire the guard and run a futile
    # dispatch scan, so a ping's wake queues behind the herd.  In event
    # mode parked workers are untouched.  That asymmetry -- O(fleet)
    # wakeups per guard exit vs. O(1) targeted -- is what this metric
    # exists to expose; an utterly idle fleet would hide it.
    churn_stop = [False]

    def _make_churn(sleep_s):
        def _churn(now):
            if churn_stop[0]:
                return "done"
            time.sleep(sleep_s)
            return "yield"
        return _churn

    # Pin churn away from the serve loop's slot (cpuset analogue): live
    # preemption is cooperative, so a ping that lands behind a mid-chunk
    # background sleep waits it out *identically in both modes* -- that
    # queueing delay is placement noise, not the dispatch cost under test.
    churn_sleeps = (2e-3, 3e-3, 4e-3, 5e-3)      # staggered epilogue rate
    churn_group = kernel.create_group(
        "churn", Tier.BACKGROUND, 100.0,
        slot_affinity=frozenset(range(N_SLOTS - len(churn_sleeps), N_SLOTS)))
    for i, sleep_s in enumerate(churn_sleeps):
        kernel.wake(LiveJob(churn_group, _make_churn(sleep_s),
                            name=f"churn{i}"))

    # GC off for the probe: a gen-2 collection pause lands on whichever
    # ping is unlucky and would report the allocator, not the dispatch
    # path, at p99.  (Identical treatment for both modes.)
    n_pings = 600 if model_name == "stub" else 80
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        ping_t0 = kernel.executor.now
        for _ in range(n_pings):
            time.sleep(0.005)                # let the decode loop park
            ping = engine.submit(
                Request(prompt=rng.integers(1, vocab, 4).astype(np.int32),
                        max_new_tokens=2))
            ping.done_event.wait(timeout=10)
        ping_t1 = kernel.executor.now
    finally:
        if gc_was_enabled:
            gc.enable()
    churn_stop[0] = True
    time.sleep(0.01)                         # churn job observes the stop
    engine.stop()
    kernel.stop()

    done = [r for r in reqs if r.ok]
    failed = [r for r in reqs if r.finished is not None and not r.ok]
    tokens = sum(len(r.tokens) for r in done)
    wall = t_end - t_start
    inter = [r for r in done if r.tier != "background"]
    bulk = [r for r in done if r.tier == "background"]
    ttft = [(r.first_token - r.submitted) * 1e3 for r in inter
            if r.first_token is not None]
    bulk_ttft = [(r.first_token - r.submitted) * 1e3 for r in bulk
                 if r.first_token is not None]
    itl = [(b - a) * 1e3 for r in inter
           for a, b in zip(r.token_times, r.token_times[1:])]
    # Worker wakeup latency: wake -> first dispatch of the *time-sensitive*
    # serve group only (the decode loop parking and being woken by probe
    # arrivals), measured over the probe window.  Bulk-group delays are
    # tier queueing -- background jobs wait for slack by design -- not
    # dispatch latency.
    delays = wakeup_delays([e for e in tracer.events
                            if ping_t0 <= e.t <= ping_t1])
    wakes = [d * 1e6 for d in delays.get(engine.group.name, [])]
    return {
        "name": f"{model_name}.{mode}",
        "model": model_name, "mode": mode,
        "n_slots": N_SLOTS, "max_batch": MAX_BATCH,
        "duration_s": round(wall, 3),
        "requests": {"submitted": len(reqs), "completed": len(done),
                     "failed": len(failed)},
        "tokens": tokens,
        "tokens_per_sec": round(tokens / wall, 1) if wall > 0 else 0.0,
        "ttft_ms": {"p50": round(percentile(ttft, 50), 3) if ttft else None,
                    "p99": round(percentile(ttft, 99), 3) if ttft else None},
        "bulk_ttft_ms": {
            "p50": round(percentile(bulk_ttft, 50), 3) if bulk_ttft else None,
            "p99": round(percentile(bulk_ttft, 99), 3) if bulk_ttft else None},
        "itl_ms": {"p50": round(percentile(itl, 50), 3) if itl else None,
                   "p99": round(percentile(itl, 99), 3) if itl else None},
        "lock_hold_us": {"p50": round(stats["lock_hold_p50_us"], 2),
                         "p99": round(stats["lock_hold_p99_us"], 2),
                         "max": round(stats["lock_hold_max_us"], 2)},
        "wakeup_us": {"p50": round(percentile(wakes, 50), 2) if wakes else None,
                      "p99": round(percentile(wakes, 99), 2) if wakes else None,
                      "n": len(wakes)},
        "engine": stats,
    }


# ---------------------------------------------------------------------------
# Baseline comparison (CI regression gate) -- microbench convention
# ---------------------------------------------------------------------------

def _calibration_us() -> float:
    """Wall time of a fixed pure-Python loop (best of 3): a proxy for this
    machine's interpreter speed, so the regression gate compares code, not
    hardware."""
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        x = 0
        for i in range(200_000):
            x += i ^ (x >> 3)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def compare_to_baseline(doc: dict, baseline: dict,
                        max_regression: float) -> list:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures = []
    base_rows = {r["name"]: r for r in baseline.get("results", [])}
    scale = 1.0
    if baseline.get("calib_us") and doc.get("calib_us"):
        scale = baseline["calib_us"] / doc["calib_us"]
    for row in doc["results"]:
        base = base_rows.get(row["name"])
        if base is None:
            continue
        b, n = base["tokens_per_sec"] * scale, row["tokens_per_sec"]
        if b > 0 and n < b * (1.0 - max_regression):
            failures.append(
                f"{row['name']}: tokens/sec {n:.0f} < "
                f"{(1.0 - max_regression):.2f} * machine-scaled baseline "
                f"{b:.0f}")
    return failures


def _speedups(results: list) -> dict:
    rows = {r["name"]: r for r in results}
    out = {}
    for model in {r["model"] for r in results}:
        base = rows.get(f"{model}.baseline")
        hot = rows.get(f"{model}.hotpath")
        if not base or not hot:
            continue
        entry = {}
        if base["tokens_per_sec"]:
            entry["tokens_per_sec"] = round(
                hot["tokens_per_sec"] / base["tokens_per_sec"], 2)
        bp, hp = base["wakeup_us"]["p99"], hot["wakeup_us"]["p99"]
        if bp and hp:
            entry["wakeup_p99"] = round(bp / hp, 2)
        bl, hl = base["lock_hold_us"]["p99"], hot["lock_hold_us"]["p99"]
        if bl and hl:
            entry["lock_hold_p99"] = round(bl / hl, 2)
        out[model] = entry
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_all(short: bool, only: Optional[list] = None) -> dict:
    duration = 3.0 if short else 8.0
    models = ["stub"] if short else ["stub", "real"]
    results = []
    for model in models:
        for mode in ("baseline", "hotpath"):
            name = f"{model}.{mode}"
            if only and not any(name.startswith(p) or p.startswith(name)
                                or mode.startswith(p) for p in only):
                continue
            row = bench_one(model, mode, duration)
            print(f"{row['name']}: {row['tokens']} tokens in "
                  f"{row['duration_s']:.2f}s = {row['tokens_per_sec']:.0f} "
                  f"tok/s, ttft p99={row['ttft_ms']['p99']}ms, "
                  f"itl p99={row['itl_ms']['p99']}ms, "
                  f"lock p99={row['lock_hold_us']['p99']}us, "
                  f"wakeup p99={row['wakeup_us']['p99']}us "
                  f"(n={row['wakeup_us']['n']})", flush=True)
            results.append(row)
    doc = {"schema": "repro.serving_bench/v1", "short": short,
           "calib_us": round(_calibration_us(), 2), "results": results,
           "speedup": _speedups(results)}
    if doc["speedup"]:
        print(f"speedup: {json.dumps(doc['speedup'])}", flush=True)
    return doc


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--short", action="store_true",
                    help="CI mode: stub model only, shorter load window")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the JSON document to PATH (e.g. BENCH_10.json)")
    ap.add_argument("--only", default=None,
                    help="comma-separated scenario prefixes "
                         "(stub.hotpath, real, baseline)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed baseline JSON to gate regressions against")
    ap.add_argument("--max-regression", type=float, default=0.50,
                    help="fail if tokens/sec drops more than this fraction "
                         "below baseline (default 0.50; live timing is noisy)")
    args = ap.parse_args(argv)

    # Latency benchmark on a small box: the default 5 ms GIL switch
    # interval means a freshly woken worker can sit a full quantum
    # behind another thread's bytecode burst, which swamps the tails
    # we are trying to measure.  Pin it low for both modes equally.
    sys.setswitchinterval(0.0001)

    baseline = None
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)

    only = args.only.split(",") if args.only else None
    doc = run_all(args.short, only=only)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out} ({len(doc['results'])} results)")

    if baseline is not None:
        failures = compare_to_baseline(doc, baseline, args.max_regression)
        if failures:
            for fail in failures:
                print(f"REGRESSION: {fail}", file=sys.stderr)
            return 1
        print(f"baseline gate passed "
              f"(max regression {args.max_regression:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
