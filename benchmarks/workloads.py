"""Shared benchmark config: paper-matched scenario parameters."""
DURATION = 20.0      # measurement window (paper: 60s; scaled for CI)
WARMUP = 5.0         # paper: 60s warm-up
SLOTS = 8            # paper section 6.1 uses 8 cores
WORKERS = 8

SCHEDULERS = ["ufs", "vdf", "idle", "fifo", "rr"]
