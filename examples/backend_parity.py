"""One scheduling core, two execution backends.

The same ``UFSPolicy`` drives the same mixed workload shape twice: once
through ``SchedKernel`` (discrete-event ``SimExecutor``) and once through
``LiveKernel`` (``ThreadExecutor``, real threads and real sleeps). The
policy code is byte-identical in both runs -- only the Executor differs
(DESIGN.md section 2) -- so the qualitative behaviour must match: the
background job is preempted whenever time-sensitive work wakes, and never
preempted when running alone.

  PYTHONPATH=src python examples/backend_parity.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks.parity import _live_run, _sim_run  # noqa: E402

print("=== same UFS policy, sim vs live executor (1 slot, TS bursty vs "
      "BG bound) ===")
for backend, runner, dur in (("sim ", _sim_run, 3.0), ("live", _live_run, 1.0)):
    p_mixed, ts_cpu, bg_cpu = runner(True, dur)
    p_solo, _, _ = runner(False, dur)
    total = (ts_cpu + bg_cpu) or 1.0
    print(f"{backend}  mixed: {p_mixed:5d} preemptions, TS share "
          f"{100 * ts_cpu / total:3.0f}%   solo: {p_solo} preemptions")
print("-> both backends: preemptions only under contention, zero solo; the "
      "TS class always gets its full demand first.")
