"""Mixed-workload serving: the paper's deployment story on real JAX work.

A live UFS kernel schedules one device slot between:
  * an inference engine serving interactive requests (time-sensitive tier),
  * a background trainer running microbatches (background tier),
with hint-instrumented engine locks guarding the KV-slot allocator.

Compare against --policy fifo / rr / vdf to see background work delay the
interactive class.

  PYTHONPATH=src python examples/mixed_serving.py [--policy ufs]
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = ["serve", "--arch", "qwen2-0.5b", "--reduced",
                "--requests", "8", "--max-new-tokens", "8",
                "--background-train"] + sys.argv[1:]
    serve.main()
