"""Priority-inversion demo (Table 4), full matrix: every scheduler, with
and without application hinting, with per-event trace output.

The hinted UFS run captures a scheduler trace; the boost of the background
lock holder shows up as a detectable inversion span (boost -> unboost with
its resolution time), exactly how the paper attributes waiter latency to
priority inversion from its eBPF tracepoints.

  PYTHONPATH=src python examples/priority_inversion_demo.py
"""
from repro.core import Job, SchedTracer, Tier, build_kernel, detect_inversions
from repro.core.workloads import burner, holder, waiter

print(f"{'scheduler':<14} {'holder done':>12} {'waiter lock':>12} "
      f"{'waiter done':>12}  notes")
traced_inversions = []
for pol, hints in (("ufs", False), ("vdf", False), ("idle", False),
                   ("fifo", False), ("rr", False), ("ufs", True)):
    # Kind-filtered: boost and lock events are rare, so the ring never
    # wraps over them even across the full 1500 s horizon.
    tracer = SchedTracer(kinds={"boost", "unboost", "lock_wait",
                                "lock_acquire", "lock_release"}) if hints else None
    k = build_kernel("sim", policy=pol, hints_enabled=hints, tracer=tracer)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("spin")
    h = Job(bg, behavior=holder(lock, compute=3.0), name="holder")
    w = Job(ts, behavior=waiter(lock), name="waiter")
    b = Job(ts, behavior=burner(), name="burner")
    for j in (h, w, b):
        j.pinned_slot = 0
        k.add_job(j)
    k.run(1500.0)
    hl = k.metrics.request_latency.get("bg", [])
    wl = k.metrics.request_latency.get("ts", [])
    wacq = lock.acquired_at.get(w.jid)

    def f(v):
        return f"{v:8.1f}s" if v is not None else ("   PANIC" if k.metrics.panics
                                                   else "   never")
    notes = []
    if h.boost_count:
        notes.append(f"holder boosted {h.boost_count}x")
    if k.metrics.panics:
        notes.append("stuck-spinlock watchdog fired")
    if tracer is not None:
        traced_inversions = detect_inversions(tracer.events)
    name = pol + ("+hints" if hints else "")
    print(f"{name:<14} {f(hl[0] if hl else None):>12} {f(wacq):>12} "
          f"{f(wl[0] + 0.1 if wl else None):>12}  {'; '.join(notes)}")

print("\ninversion spans detected in the ufs+hints trace:")
for inv in traced_inversions:
    res = (f"resolved in {inv['resolution']:.3f}s"
           if inv["resolution"] is not None else "unresolved")
    print(f"  {inv['job']} boosted into {inv['boost_group']!r} "
          f"at t={inv['t_boost']:.3f}s, {res}")
print("\npaper Table 4: EEVDF panics; FIFO strands the waiter; RR takes ~71 s;"
      "\nUFS with hints finishes in ~2x the no-contention baseline.")
