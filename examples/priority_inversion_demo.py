"""Priority-inversion demo (Table 4), full matrix: every scheduler, with
and without application hinting, with per-event trace output.

  PYTHONPATH=src python examples/priority_inversion_demo.py
"""
from repro.core import Job, SchedKernel, Tier, make_policy
from repro.core.workloads import burner, holder, waiter

print(f"{'scheduler':<14} {'holder done':>12} {'waiter lock':>12} "
      f"{'waiter done':>12}  notes")
for pol, hints in (("ufs", False), ("vdf", False), ("idle", False),
                   ("fifo", False), ("rr", False), ("ufs", True)):
    k = SchedKernel(1, make_policy(pol), hints_enabled=hints)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("spin")
    h = Job(bg, behavior=holder(lock, compute=3.0), name="holder")
    w = Job(ts, behavior=waiter(lock), name="waiter")
    b = Job(ts, behavior=burner(), name="burner")
    for j in (h, w, b):
        j.pinned_slot = 0
        k.add_job(j)
    k.run(1500.0)
    hl = k.metrics.request_latency.get("bg", [])
    wl = k.metrics.request_latency.get("ts", [])
    wacq = lock.acquired_at.get(w.jid)

    def f(v):
        return f"{v:8.1f}s" if v is not None else ("   PANIC" if k.metrics.panics
                                                   else "   never")
    notes = []
    if h.boost_count:
        notes.append(f"holder boosted {h.boost_count}x")
    if k.metrics.panics:
        notes.append("stuck-spinlock watchdog fired")
    name = pol + ("+hints" if hints else "")
    print(f"{name:<14} {f(hl[0] if hl else None):>12} {f(wacq):>12} "
          f"{f(wl[0] + 0.1 if wl else None):>12}  {'; '.join(notes)}")
print("\npaper Table 4: EEVDF panics; FIFO strands the waiter; RR takes ~71 s;"
      "\nUFS with hints finishes in ~2x the no-contention baseline.")
