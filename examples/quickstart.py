"""Quickstart: the UFS scheduler in 60 seconds.

Runs the paper's MIN:MAX mixed workload in simulation under UFS and the
EEVDF baseline, then the Table 4 priority-inversion micro-experiment --
reproducing the paper's headline numbers on your laptop.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Job, Tier, build_kernel
from repro.core.experiment import scenario
from repro.core.workloads import burner, holder, waiter

print("=== mixed DB workload, MIN:MAX (8 bursty hi-prio + 8 bound lo-prio, "
      "8 slots) ===")
for pol in ("vdf", "ufs"):
    r = scenario(pol, "minmax", n_slots=8, n=8, duration=10.0, warmup=3.0)
    ls = r.lat("ts")
    label = "EEVDF" if pol == "vdf" else "UFS"
    print(f"{label:6s} bursty {r.thr('ts'):7.1f} tx/s   "
          f"mean {ls['mean']*1e3:5.2f} ms   p95 {ls['p95']*1e3:5.2f} ms   "
          f"(background {r.thr('bg'):.2f} q/s)")
print("-> UFS keeps time-sensitive throughput at SOLO level; EEVDF loses ~half.")

print("\n=== priority inversion (holder/waiter/burner pinned to 1 slot) ===")
for pol, hints in (("vdf", False), ("ufs", True)):
    k = build_kernel("sim", policy=pol, hints_enabled=hints)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("spin")
    h = Job(bg, behavior=holder(lock, compute=1.0), name="holder")
    w = Job(ts, behavior=waiter(lock), name="waiter")
    b = Job(ts, behavior=burner(), name="burner")
    for j in (h, w, b):
        j.pinned_slot = 0
        k.add_job(j)
    k.run(1200.0)
    wl = k.metrics.request_latency.get("ts", [])
    label = "EEVDF" if pol == "vdf" else "UFS+hints"
    if k.metrics.panics:
        print(f"{label:10s} waiter: stuck-spinlock PANIC (priority inversion)")
    else:
        print(f"{label:10s} waiter completed in {wl[0]:.1f} s "
              f"(holder boosted {h.boost_count}x)")
