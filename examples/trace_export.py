"""Capture a scheduler trace from a sim run and export it for Perfetto.

`build_kernel(..., trace=True)` attaches a ring-buffer tracer; every
lifecycle edge (wake, enqueue, dispatch, start/stop, preempt, kick, boost,
lock acquire/release) lands in it as a structured event.  The export is
Chrome trace_event JSON: open it at https://ui.perfetto.dev to see one
track per slot, one per workload group, and instant markers for kicks and
boosts -- the userspace analogue of the paper's eBPF sched_switch traces.

  PYTHONPATH=src python examples/trace_export.py [out.json]
"""
import sys

from repro.core import (KernelReport, SchedTracer, slot_busy_from_trace,
                        wakeup_delays, write_chrome_trace)
from repro.core.experiment import run_mix

OUT = sys.argv[1] if len(sys.argv) > 1 else "trace.json"
SLOTS, WARMUP, DUR = 2, 0.3, 2.0

tracer = SchedTracer()
r = run_mix("ufs", n_slots=SLOTS, n_bursty=SLOTS, n_bound=SLOTS,
            duration=DUR, warmup=WARMUP, tracer=tracer)
end = WARMUP + DUR

n = write_chrome_trace(tracer.events, OUT, end=end)
s = tracer.summary()
print(f"wrote {OUT}: {n} trace records from {s.events} events "
      f"({s.dropped} dropped) -- open it at https://ui.perfetto.dev")

# The trace is a second, independent accounting path: the per-slot busy
# timeline it implies matches the kernel's own charge-time metrics.
busy = slot_busy_from_trace(tracer.events, SLOTS, kind="bursty",
                            window=(WARMUP, end), end=end)
print(f"bursty busy-seconds per slot, from the trace:   "
      f"{[f'{b:.3f}' for b in busy]}")
print(f"... and from Metrics.slot_utilization:          "
      f"{[f'{b:.3f}' for b in r.metrics.slot_utilization('bursty', SLOTS)]}")

wd = wakeup_delays(tracer.events)
for g in sorted(wd):
    d = wd[g]
    print(f"wakeup delay {g}: mean {sum(d)/len(d)*1e6:.0f} us "
          f"max {max(d)*1e6:.0f} us (n={len(d)})")
