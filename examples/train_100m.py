"""End-to-end training driver example: train a ~100M-parameter llama-family
model for a few hundred steps on synthetic data, with fault-tolerant
checkpointing.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

(~100M params: 12L x d512 on a 32k vocab; on CPU this takes a while --
use --tiny for a quick pass.)
"""
import argparse
import dataclasses
import sys

from repro.configs.base import ArchConfig
from repro.launch import train as train_mod
from repro.configs import base as cfgbase

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
args, rest = ap.parse_known_args()

if args.tiny:
    cfg = ArchConfig(name="demo-tiny", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                     head_dim=16, dtype="float32", remat=False)
    batch, seq = 8, 64
else:
    cfg = ArchConfig(name="demo-100m", family="dense", n_layers=12, d_model=512,
                     n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=32_000,
                     dtype="float32", remat=False)
    batch, seq = 8, 256

cfgbase.register(cfg)
sys.argv = ["train", "--arch", cfg.name, "--steps", str(args.steps),
            "--batch", str(batch), "--seq", str(seq),
            "--ckpt-dir", "/tmp/repro_ckpt_100m", "--ckpt-every", "100",
            "--resume"] + rest
train_mod.main()
