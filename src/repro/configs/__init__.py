from .base import (ArchConfig, MLAConfig, MoEConfig, SSMConfig, ShapeConfig,
                   SHAPES, all_archs, cells, get_arch)

__all__ = ["ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
           "SHAPES", "all_archs", "cells", "get_arch"]
