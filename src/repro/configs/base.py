"""Architecture configs and input-shape registry.

Every assigned architecture gets an :class:`ArchConfig` built from the exact
public numbers in the assignment (see per-arch modules), plus a REDUCED
config of the same family for CPU smoke tests. Input shapes are the four
assigned cells (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int               # routed experts
    n_shared: int               # shared (always-on) experts
    top_k: int
    expert_ff: int              # per-expert intermediate size
    n_expert_groups: int = 1    # group-limited routing (deepseek)
    router_scale: float = 1.0
    padded_routed: int = 0      # routed experts padded for EP divisibility

    def routed_total(self) -> int:
        return self.padded_routed or self.n_routed


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16          # mamba state size per channel
    conv_width: int = 4
    expand: int = 2
    slstm_every: int = 0         # xlstm: every k-th block is sLSTM (0 = none)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    first_k_dense: int = 0       # leading dense layers in MoE stacks
    # hybrid / attention structure
    sliding_window: int = 0      # 0 = full attention everywhere
    global_attn_layers: tuple = ()   # layers that stay full-attn despite SWA
    parallel_ssm: bool = False   # hymba: attention and SSM heads in parallel
    # enc-dec / multimodal frontends (stubs provide embeddings)
    encoder_layers: int = 0
    encoder_len: int = 1024      # stub frame/patch sequence length
    vision_tokens: int = 0       # vlm prefix tokens
    # training
    dtype: str = "bfloat16"
    remat: bool = True
    # misc
    max_position: int = 1 << 20

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def attention_kind(self) -> str:
        if self.mla is not None:
            return "mla"
        return "gqa"

    def is_subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context (long_500k cell)?"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.sliding_window > 0:
            return True
        return False

    def has_decoder(self) -> bool:
        return True   # none of the assigned archs is encoder-only

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 + self.first_k_dense),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            dtype="float32",
            remat=False,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_len=16,
            vision_tokens=min(self.vision_tokens, 8),
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            global_attn_layers=tuple(g for g in self.global_attn_layers if g < 2),
            first_k_dense=min(self.first_k_dense, 1),
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, n_routed=4, n_shared=min(self.moe.n_shared, 1),
                                top_k=2, expert_ff=32, n_expert_groups=1, padded_routed=4)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, state_dim=4)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(_REGISTRY)}")


def all_archs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from . import (stablelm_3b, llama3_2_1b, qwen2_0_5b, granite_8b,          # noqa: F401
                   seamless_m4t_medium, hymba_1_5b, internvl2_1b,
                   qwen2_moe_a2_7b, deepseek_v3_671b, xlstm_350m)


def cells(include_skipped: bool = False):
    """All (arch x shape) dry-run cells; long_500k only for sub-quadratic
    archs unless include_skipped."""
    out = []
    for name in all_archs():
        cfg = get_arch(name)
        for sname, shape in SHAPES.items():
            skipped = (sname == "long_500k" and not cfg.is_subquadratic())
            if skipped and not include_skipped:
                continue
            out.append((name, sname, skipped))
    return out
