"""deepseek-v3-671b [moe] -- 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256e top-8 -- MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]

Interpretation of the assigned numbers against the public config:
* d_ff=2048 is the per-expert (and shared-expert) intermediate size
  (``moe_intermediate_size``); the 3 leading dense layers use 18432
  (``intermediate_size``), per the HF config.
* MLA dims from the public config: q_lora 1536, kv_lora 512, nope 128,
  rope 64, v 128.
* MTP (multi-token prediction, depth 1) is implemented as an optional extra
  scan block + head, enabled for training configs.
"""
from .base import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                # dense-layer FF (first_k_dense layers)
    vocab_size=129280,
    head_dim=192,              # qk_nope (128) + qk_rope (64)
    first_k_dense=3,
    moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, expert_ff=2048,
                  n_expert_groups=8, router_scale=2.5),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_theta=10_000.0,
))
