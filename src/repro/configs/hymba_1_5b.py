"""hymba-1.5b [hybrid] -- 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 -- parallel attn+mamba heads [arXiv:2411.13676; hf]

Hymba fuses attention heads and mamba (SSM) heads *in parallel* within each
block, with sliding-window attention in all but three global layers
(first / middle / last) -- which keeps it sub-quadratic and long_500k-capable.
"""
from .base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    parallel_ssm=True,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    rope_theta=10_000.0,
))
