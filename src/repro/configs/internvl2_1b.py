"""internvl2-1b [vlm] -- 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 -- InternViT + InternLM2/Qwen2 backbone [arXiv:2404.16821; hf]

The InternViT vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (batch, vision_tokens, d_model) which are
prepended to the token embedding sequence; the LM backbone is full.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    vision_tokens=256,
    rope_theta=1_000_000.0,
))
