"""qwen2-moe-a2.7b [moe] -- 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 -- 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 routed experts are padded to 64 for clean expert-parallel sharding over
the 16-way model axis (padding experts receive -inf router logits and zero
weights; they are never selected). Recorded in DESIGN.md section 9.
"""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                 # per-expert intermediate (assignment d_ff)
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(n_routed=60, n_shared=4, top_k=4, expert_ff=1408,
                  padded_routed=64),
    rope_theta=1_000_000.0,
))
