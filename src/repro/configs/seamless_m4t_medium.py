"""seamless-m4t-medium [audio] -- 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 -- enc-dec, multimodal [arXiv:2308.11596; hf]

The audio frontend (fbank conv feature extractor) is a STUB: ``input_specs``
provides precomputed frame embeddings of shape (batch, encoder_len, d_model);
the transformer backbone (12L encoder + 12L decoder with cross-attention)
is implemented in full.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,               # decoder layers
    encoder_layers=12,
    encoder_len=1024,          # stub audio frames after feature extraction
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10_000.0,
))
