"""xlstm-350m [ssm] -- 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304
-- sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up/down projections (expand factor 2)
instead of a separate FFN. Every 6th block is an sLSTM block (scalar
memory); the rest are mLSTM (matrix memory, chunkwise-parallel -- the
Pallas kernel target). Recurrent state is O(1) in sequence length, so
long_500k runs.
"""
from .base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, slstm_every=6),
))
