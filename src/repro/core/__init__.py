"""repro.core -- UFS: the selectively unfair scheduler (the paper's
contribution), plus the scheduling kernel and baseline policies.

Public surface:

* :class:`SchedKernel`, :class:`Slot`, :class:`SimClock` -- event-driven core
* :class:`UFSPolicy` and baselines via :func:`make_policy`
* :class:`Job`, :class:`WorkloadGroup`, :class:`Tier` -- schedulable entities
* :class:`HintTable` -- application-based scheduler hinting (eBPF-map analogue)
* workload generators for the paper's experiments
"""
from .task import (Job, JobState, Tier, WorkloadGroup, Burst, Block,
                   RequestBegin, RequestEnd, Exit)
from .base import SchedCore, Executor, Policy, Slot, DEFAULT_SLICE
from .kernel import SchedKernel, SimClock, SimExecutor
from .live import LiveKernel, LiveJob, LiveLock, ThreadExecutor
from .hints import HintTable
from .locks import SimLock, spin_acquire
from .metrics import Metrics, percentile
from .ufs import UFSPolicy
from .policies import make_policy, POLICIES

__all__ = [
    "Job", "JobState", "Tier", "WorkloadGroup", "Burst", "Block",
    "RequestBegin", "RequestEnd", "Exit",
    "SchedCore", "Executor", "Policy", "Slot", "DEFAULT_SLICE",
    "SchedKernel", "SimClock", "SimExecutor",
    "LiveKernel", "LiveJob", "LiveLock", "ThreadExecutor",
    "HintTable", "SimLock", "spin_acquire", "Metrics", "percentile",
    "UFSPolicy", "make_policy", "POLICIES",
]
