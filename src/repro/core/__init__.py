"""repro.core -- UFS: the selectively unfair scheduler (the paper's
contribution), plus the scheduling kernel and baseline policies.

Public surface:

* :func:`build_kernel` -- the one construction path for both backends;
  :class:`KernelReport` -- the one telemetry read-out (metrics + trace)
* :class:`SchedKernel`, :class:`Slot`, :class:`SimClock` -- event-driven core
* :class:`SchedTracer` -- bounded ring buffer of scheduler lifecycle events
  (eBPF-tracepoint analogue) with Chrome-trace export and derived analyses
* :class:`UFSPolicy` and baselines via :func:`make_policy`
* :class:`Job`, :class:`WorkloadGroup`, :class:`Tier` -- schedulable entities
* :class:`HintTable` -- application-based scheduler hinting (eBPF-map analogue)
* workload generators for the paper's experiments
"""
from .task import (Job, JobState, Tier, WorkloadGroup, Burst, Block,
                   RequestBegin, RequestEnd, Exit, RetryPolicy)
from .faults import (FaultInjected, FaultInjector, crashing_chunk,
                     crashy_behavior, crashing_holder, occupy_lock,
                     drain_after)
from .trace import (SchedTracer, TraceEvent, TraceSummary, summarize,
                    busy_intervals, slot_busy_from_trace, wakeup_delays,
                    detect_inversions, to_chrome_trace, write_chrome_trace,
                    validate_events, validate_chrome_trace, TraceSchemaError)
from .base import SchedCore, Executor, Policy, Slot, DEFAULT_SLICE
from .kernel import SchedKernel, SimClock, SimExecutor
from .live import LiveKernel, LiveJob, LiveLock, ThreadExecutor
from .build import build_kernel, KernelReport
from .hints import HintTable
from .locks import SimLock, spin_acquire
from .metrics import Metrics, percentile, percentile_sorted
from .ufs import UFSPolicy
from .policies import make_policy, POLICIES

__all__ = [
    "Job", "JobState", "Tier", "WorkloadGroup", "Burst", "Block",
    "RequestBegin", "RequestEnd", "Exit", "RetryPolicy",
    "FaultInjected", "FaultInjector", "crashing_chunk", "crashy_behavior",
    "crashing_holder", "occupy_lock", "drain_after",
    "SchedCore", "Executor", "Policy", "Slot", "DEFAULT_SLICE",
    "SchedKernel", "SimClock", "SimExecutor",
    "LiveKernel", "LiveJob", "LiveLock", "ThreadExecutor",
    "build_kernel", "KernelReport",
    "SchedTracer", "TraceEvent", "TraceSummary", "summarize",
    "busy_intervals", "slot_busy_from_trace", "wakeup_delays",
    "detect_inversions", "to_chrome_trace", "write_chrome_trace",
    "validate_events", "validate_chrome_trace", "TraceSchemaError",
    "HintTable", "SimLock", "spin_acquire", "Metrics", "percentile",
    "percentile_sorted",
    "UFSPolicy", "make_policy", "POLICIES",
]
