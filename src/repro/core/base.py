"""The shared scheduling core: one policy surface, pluggable execution.

This module is the single implementation of the scheduling machinery that
both execution modes share (DESIGN.md section 2).  :class:`SchedCore` owns

* **slots** -- execution units (device slots on a pod; CPUs in the paper),
  each with a local DSQ;
* the **group/job registries** (cgroup analogue, task table);
* the **job lifecycle** -- enqueue (wake/requeue), dispatch
  (:meth:`SchedCore.schedule_next`), start/stop bookkeeping, preemption;
* **hint -> boost wiring** (priority-inversion avoidance), **metrics**, and
  the **trace plane** (:mod:`repro.core.trace`): every lifecycle edge emits
  a structured event into an optional :class:`SchedTracer`;

parameterized by a narrow :class:`Executor` protocol with two backends:

* ``SimExecutor`` (``repro.core.kernel``) -- the deterministic discrete-event
  clock driving generator-based jobs in virtual time;
* ``ThreadExecutor`` (``repro.core.live``) -- worker threads driving real
  (JAX) ``run_chunk`` jobs, with chunk-granular preempt polling.

Policies (:class:`Policy`) attach to the *core*, never to a backend, so the
same policy object behaves identically under simulation and deployment --
the sim/live parity invariant (tests/test_parity.py).
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from contextlib import nullcontext
from typing import Callable, ContextManager, Optional

from .dsq import GroupDSQ, LocalDSQ
from .hints import HintTable
from .metrics import Metrics
from .task import Job, JobState, Tier, WorkloadGroup
from .trace import SchedTracer

DEFAULT_SLICE = 0.003  # 3 ms bounded execution interval (paper section 5.1.1)

_NULL_GUARD = nullcontext()


def _trace_noop(kind, *, slot=None, job=None, **args) -> None:
    """Pre-bound no-op installed as ``core.trace`` when no tracer is
    attached: plain function, no self binding, no tracer lookup.  Hot
    emitters additionally guard on ``core._traced`` so untraced runs never
    even build the kwargs dict."""


class Slot:
    """An execution unit: one mesh-slice program context (a CPU, in the paper).

    Holds only backend-independent execution state.  Policy-private state
    (e.g. the RT fair-server window) lives in the policy; backend-private
    state (run-end tokens, preempt flags) lives in the executor.
    """

    def __init__(self, sid: int):
        self.sid = sid
        self.local_dsq = LocalDSQ()
        self.current: Optional[Job] = None
        self.run_started = 0.0
        self.slice_budget = 0.0
        self.online = True            # False once drained (elasticity)

    @property
    def idle(self) -> bool:
        return self.current is None and len(self.local_dsq) == 0

    def __repr__(self) -> str:  # pragma: no cover
        cur = self.current.name if self.current else "-"
        return f"Slot({self.sid}, cur={cur}, q={len(self.local_dsq)})"


class Policy(ABC):
    """sched_ext-style policy callback surface (DESIGN.md section 3).

    ``attach`` receives the :class:`SchedCore` (the facades subclass it, so
    ``self.kernel`` works against either backend).  Callbacks are always
    invoked with the core's mutation guard held; policies never advance time
    themselves, they only mutate queue state and request kicks.
    """

    name = "abstract"

    def attach(self, kernel: "SchedCore") -> None:
        self.kernel = kernel

    @abstractmethod
    def enqueue(self, job: Job, requeue: bool = False) -> None:
        """Job became runnable (wakeup) or must be requeued (preempt/slice)."""

    @abstractmethod
    def dispatch(self, slot: Slot) -> None:
        """Slot needs work and its local DSQ is empty: pull if possible."""

    def pick_next(self, slot: Slot):
        """Select the next job for a free slot: local DSQ first, then pull
        via :meth:`dispatch`. Policies may override the pick order (e.g. the
        RT fair-server window)."""
        nxt = slot.local_dsq.pop_front()
        while nxt is not None and nxt.state != JobState.RUNNABLE:
            nxt = slot.local_dsq.pop_front()
        if nxt is None:
            k = self.kernel
            k.metrics.dispatches += 1
            if k._traced:
                k.trace("dispatch", slot=slot.sid)
            self.dispatch(slot)
            nxt = slot.local_dsq.pop_front()
            while nxt is not None and nxt.state != JobState.RUNNABLE:
                nxt = slot.local_dsq.pop_front()
        return nxt

    def running(self, job: Job, slot: Slot) -> None:
        """Job starts executing on slot."""

    def stopping(self, job: Job, slot: Slot, used: float) -> None:
        """Job stops executing (block/preempt/slice/exit); charge service."""

    def task_slice(self, job: Job) -> float:
        return DEFAULT_SLICE

    def on_boost(self, job: Job) -> None:
        """Hint boost fired for a queued/running background job."""

    def on_unboost(self, job: Job) -> None:
        pass

    def periodic(self) -> None:
        """Optional periodic work (load balancing); driven by the core timer."""

    periodic_interval: Optional[float] = None

    def queued_count(self) -> int:
        """Number of runnable jobs waiting in this policy's queues (local
        DSQs + group DSQs).  Used by event-driven executors to bound how
        many parked workers an enqueue wakes.  Policies with private queues
        (e.g. the RT global fair rq) must override and add them in."""
        k = self.kernel
        n = sum(len(s.local_dsq) for s in k.slots if s.online)
        n += sum(len(g.dsq) for g in k.groups.values() if g.dsq is not None)
        return n


class Executor(ABC):
    """Narrow backend protocol: how the core's decisions are carried out.

    The core calls *down* into the executor for time, deferred callbacks,
    mutual exclusion, and kick delivery; the executor calls *up* into the
    core's lifecycle methods (``schedule_next`` / ``start_job`` /
    ``stop_job`` / ``preempt_slot``) when its execution model needs them.

    ``single_threaded`` declares that all core/policy/tracer access happens
    on one thread, letting the core drop tracer locking (sim backend).
    """

    core: "SchedCore"
    single_threaded = False

    def bind(self, core: "SchedCore") -> None:
        self.core = core

    @property
    @abstractmethod
    def now(self) -> float:
        """Current time on this backend's clock (virtual or monotonic)."""

    @abstractmethod
    def defer(self, dt: float, fn: Callable[[], None]) -> None:
        """Run ``fn`` after ``dt`` seconds on this backend's clock."""

    @abstractmethod
    def guard(self) -> ContextManager:
        """Mutation guard for scheduler state.  Re-entrant: lifecycle code
        nests freely.  Sim: a no-op (single-threaded event loop); threads: a
        condition variable that wakes idle workers on exit."""

    @abstractmethod
    def deliver_kick(self, slot: Slot, preempt: bool) -> None:
        """Backend-specific kick delivery: sim dispatches/preempts at the
        current event; threads set a chunk-granular preempt flag and notify."""

    # ---- optional lifecycle hooks -------------------------------------
    def job_started(self, slot: Slot) -> None:
        """Dispatch tail after :meth:`SchedCore.start_job` (sim arms the
        run-end event; threads run the chunk inline in the worker)."""

    def job_stopping(self, slot: Slot) -> None:
        """Stop head before the policy is charged (sim cancels the pending
        run-end event)."""

    def job_preempted(self, job: Job, slot: Slot, used: float) -> None:
        """Continuation for a job forced off a slot mid-execution."""

    def release_held_locks(self, job: Job) -> None:
        """Force-release every engine lock the job still holds (panic /
        exit containment).  The sim backend overrides this to resume
        parked waiters that the release hands the lock to."""
        for lock in list(job.held_locks):
            lock.release(job)

    def restart_job(self, job: Job) -> bool:
        """Prepare a faulted job for a retry restart; False if this
        backend cannot restart it (quarantine instead).  Live chunks are
        plain callables and re-invoke naturally; the sim backend needs a
        ``behavior_factory`` to rebuild the dead generator."""
        return True

    def resume_retry(self, job: Job) -> None:
        """Re-admit a restarted job after its backoff delay: live wakes it
        (the chunk re-runs on dispatch); sim re-enters the phase machinery
        so the rebuilt generator wakes itself at its first burst."""
        self.core.wake(job)

    def interrupt(self, slot: Slot) -> None:
        """Force the current job off ``slot`` (drain): sim preempts at the
        current event; threads request a chunk-boundary stop."""

    def work_enqueued(self, job: Job) -> None:
        """A job just entered the policy's queues (wake/requeue).  Called
        with the mutation guard held.  Event-driven executors use this to
        arm their guard-exit wake-scan; the sim backend ignores it."""

    def slot_added(self, slot: Slot) -> None:
        """A slot joined the pool (elastic scale-up)."""

    def start(self) -> None:
        """Begin executing (no-op for the event-driven sim)."""

    def stop(self) -> None:
        """Stop executing and release backend resources."""


class SchedCore:
    """Backend-independent scheduling core shared by sim and live kernels.

    ``SchedKernel`` (sim) and ``LiveKernel`` (threads) are thin facades over
    this class; all enqueue/dispatch/start/stop/preempt logic and the
    hint-boost wiring live here, once.
    """

    def __init__(
        self,
        n_slots: int,
        policy: Policy,
        executor: Executor,
        hints: Optional[HintTable] = None,
        metrics: Optional[Metrics] = None,
        kick_latency: float = 0.0,
        hints_enabled: bool = True,
        tracer: Optional[SchedTracer] = None,
    ):
        self.executor = executor
        self.slots = [Slot(i) for i in range(n_slots)]
        self.policy = policy
        self.hints = hints or HintTable()
        self.hints_enabled = hints_enabled
        self.metrics = metrics or Metrics()
        self.tracer = tracer
        self._traced = tracer is not None
        if not self._traced:
            # Shadow the bound method with a module-level no-op: untraced
            # emit sites that aren't individually guarded cost one plain
            # call, no kwargs-dict plumbing inside.
            self.trace = _trace_noop
        elif getattr(executor, "single_threaded", False):
            # Single-threaded event loop: the tracer ring needs no mutex.
            tracer.set_threadsafe(False)
        self.kick_latency = kick_latency
        self.jobs: dict[int, Job] = {}
        self.groups: dict[str, WorkloadGroup] = {}
        self.on_panic: Optional[Callable[[Job], None]] = None
        executor.bind(self)
        policy.attach(self)
        self.hints.on_boost = self._hint_boost
        self.hints.on_unboost = self._hint_unboost
        if policy.periodic_interval:
            self._schedule_periodic()

    # ------------------------------------------------------------- utilities
    @property
    def now(self) -> float:
        return self.executor.now

    def trace(self, kind: str, *, slot: Optional[int] = None,
              job: Optional[Job] = None, **args) -> None:
        """Emit a lifecycle event into the tracer.  When untraced this
        method is shadowed by a pre-bound no-op (see ``__init__``) and hot
        emitters skip the call entirely via ``self._traced``.  The
        timestamp comes from the executor clock, so sim and live runs
        share one event schema under their respective time bases."""
        self.tracer.emit(kind, self.executor.now, slot=slot, job=job, **args)

    def create_group(self, name: str, tier: Tier, weight: float = 100.0,
                     parent: Optional[WorkloadGroup] = None, **kw) -> WorkloadGroup:
        g = WorkloadGroup(name, tier, weight, parent=parent, **kw)
        g.dsq = GroupDSQ()          # custom DSQ (background deferred dispatch)
        self.groups[name] = g
        return g

    def online_slots(self) -> list:
        return [s for s in self.slots if s.online]

    # ------------------------------------------------------------- enqueue
    def wake(self, job: Job) -> None:
        """Job becomes runnable; hand to the policy's enqueue path."""
        with self.executor.guard():
            if job.state == JobState.EXITED:
                return
            self.jobs.setdefault(job.jid, job)
            job.state = JobState.RUNNABLE
            job.wakeup_time = self.now
            job.location = None
            if self._traced:
                self.trace("wake", job=job)
                self.trace("enqueue", job=job, requeue=False)
            # Arm *before* enqueue: the policy kicks the chosen slot from
            # inside enqueue(), and event-driven executors pair each kick
            # with one armed unit (a serviced enqueue needs no wake-scan).
            self.executor.work_enqueued(job)
            self.policy.enqueue(job, requeue=False)

    def requeue(self, job: Job) -> None:
        with self.executor.guard():
            job.state = JobState.RUNNABLE
            job.location = None
            if self._traced:
                self.trace("enqueue", job=job, requeue=True)
            self.executor.work_enqueued(job)   # before enqueue: see wake()
            self.policy.enqueue(job, requeue=True)

    # ------------------------------------------------------------- kicks
    def kick(self, slot: Slot, preempt: bool = False) -> None:
        """Wake an idle slot, or (preempt=True) force the running job off.

        ``kick_latency`` models the TPU chunk-boundary adaptation: a kick
        takes effect only once the in-flight device program retires.
        """
        self.metrics.kicks += 1
        if self._traced:
            self.trace("kick", slot=slot.sid, preempt=preempt)
        if self.kick_latency > 0:
            self.executor.defer(self.kick_latency,
                                lambda: self.executor.deliver_kick(slot, preempt))
        else:
            self.executor.deliver_kick(slot, preempt)

    # ------------------------------------------------------------- dispatch
    def schedule_next(self, slot: Slot) -> None:
        """Fill a free slot: policy pick, shared start bookkeeping, then the
        backend's execution tail (arm a run-end event / run the chunk)."""
        if not slot.online or slot.current is not None:
            return
        nxt = self.policy.pick_next(slot)
        if nxt is None:
            return                               # idle
        self.start_job(slot, nxt)
        self.executor.job_started(slot)

    # --------------------------------------------------------- start / stop
    def start_job(self, slot: Slot, job: Job) -> None:
        """Shared bookkeeping when a job begins running on a slot."""
        assert job.state == JobState.RUNNABLE, f"{job} not runnable"
        job.state = JobState.RUNNING
        job.location = None
        if job.wakeup_time >= 0.0:
            self.metrics.record_wakeup(job.group.name, self.now - job.wakeup_time, self.now)
            job.wakeup_time = -1.0               # record only first start per wake
        job.prev_slot = slot.sid
        slot.current = job
        slot.run_started = self.now
        slot.slice_budget = self.policy.task_slice(job)
        if self._traced:
            self.trace("start_job", slot=slot.sid, job=job)
        self.policy.running(job, slot)

    def stop_job(self, slot: Slot, used: float, reason: str = "stop") -> Job:
        """Shared bookkeeping when the current job stops (block / preempt /
        slice expiry / exit); charges the policy and the metrics.
        ``reason`` is recorded in the trace only ("complete" / "slice" /
        "preempt" / live chunk statuses)."""
        job = slot.current
        assert job is not None
        self.executor.job_stopping(slot)         # cancel in-flight run-end event
        self.policy.stopping(job, slot, used)
        self.metrics.record_run(slot.sid, job.kind, job.group.name, used, self.now)
        if self._traced:
            self.trace("stop_job", slot=slot.sid, job=job, used=used,
                       reason=reason)
        slot.current = None
        return job

    # ------------------------------------------------------------- preempt
    def preempt_slot(self, slot: Slot) -> None:
        """Force the running job off ``slot`` now; the backend decides the
        job's continuation (burst accounting in sim; chunk epilogue live)."""
        job = slot.current
        if job is None:
            return
        self.metrics.preemptions += 1
        used = self.now - slot.run_started
        if self._traced:
            self.trace("preempt_slot", slot=slot.sid, job=job)
        self.stop_job(slot, used, reason="preempt")
        self.executor.job_preempted(job, slot, used)
        self.schedule_next(slot)

    # ------------------------------------------------------ fault containment
    def panic_job(self, job: Job, slot: Optional[Slot] = None,
                  exc: Optional[BaseException] = None, trace_back: str = "",
                  reason: str = "exception") -> None:
        """Contain a faulted job (DESIGN.md section 12).

        The one panic path for both backends: trace + count the panic,
        force-release the job's held locks (resuming any waiter the
        release hands a lock to), purge its hint-table entries so boosts
        it caused or carried expire now, notify ``on_panic``, then either
        restart the job under its :class:`~repro.core.task.RetryPolicy`
        (bounded, exponential backoff) or quarantine it to EXITED.  The
        job must already be off its slot (``stop_job`` ran)."""
        with self.executor.guard():
            if job.state == JobState.EXITED:
                return
            job.panic = True
            job.last_panic = repr(exc) if exc is not None else reason
            self.metrics.panics.append(job.name)
            if self._traced:
                self.trace("panic", job=job,
                           slot=slot.sid if slot is not None else None,
                           reason=reason, error=job.last_panic,
                           traceback=trace_back, retries=job.retries)
            self.executor.release_held_locks(job)
            self.hints.purge_job(job)
            if self.on_panic is not None:
                self.on_panic(job)
            pol = job.retry_policy
            if (pol is not None and job.retries < pol.max_retries
                    and self.executor.restart_job(job)):
                job.retries += 1
                self.metrics.retries += 1
                job.state = JobState.BLOCKED
                delay = pol.delay(job.retries)
                if self._traced:
                    self.trace("retry", job=job, attempt=job.retries,
                               delay=delay)
                self.executor.defer(delay,
                                    lambda: self.executor.resume_retry(job))
            else:
                self.quarantine_job(job)

    def quarantine_job(self, job: Job) -> None:
        """Poison a crash-looping job: EXITED for good, never re-woken
        (``wake`` refuses EXITED jobs), counted and traced."""
        with self.executor.guard():
            job.quarantined = True
            job.state = JobState.EXITED
            self.metrics.quarantines += 1
            if self._traced:
                self.trace("quarantine", job=job, retries=job.retries)

    # ----------------------------------------------------------- hint wiring
    def _hint_boost(self, job: Job) -> None:
        with self.executor.guard():
            if self._traced:
                self.trace("boost", job=job,
                           boost_group=job.boost_group.name
                           if job.boost_group else "")
            self.policy.on_boost(job)

    def _hint_unboost(self, job: Job) -> None:
        with self.executor.guard():
            if self._traced:
                self.trace("unboost", job=job)
            self.policy.on_unboost(job)

    # ----------------------------------------------------------- elasticity
    def add_slot(self) -> Slot:
        with self.executor.guard():
            slot = Slot(len(self.slots))
            self.slots.append(slot)
            self.trace("slot_add", slot=slot.sid)
        self.executor.slot_added(slot)
        return slot

    def drain_slot(self, sid: int) -> None:
        """Take a slot offline: requeue its work elsewhere (node failure /
        elastic downscale)."""
        with self.executor.guard():
            slot = self.slots[sid]
            slot.online = False
            self.trace("slot_drain", slot=sid)
            if slot.current is not None:
                self.executor.interrupt(slot)
            while True:
                job = slot.local_dsq.pop_front()
                if job is None:
                    break
                self.requeue(job)

    # ------------------------------------------------------------- periodic
    def _schedule_periodic(self) -> None:
        interval = self.policy.periodic_interval

        def tick() -> None:
            with self.executor.guard():
                self.policy.periodic()
            self.executor.defer(interval, tick)
        self.executor.defer(interval, tick)
