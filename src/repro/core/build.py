"""One construction path and one report surface for every kernel consumer.

Before this module each consumer (``experiment.py``, ``benchmarks/``,
``launch/serve.py``, ``launch/train.py``, ``examples/``) grew its own
kernel-construction convention and its own final-stats dict.  Now there is
exactly one of each:

* :func:`build_kernel` -- ``build_kernel("sim"|"live", policy=..., n_slots=...,
  tracer=...)``: a thin mode switch over the shared keyword signature of
  :class:`~repro.core.kernel.SchedKernel` and
  :class:`~repro.core.live.LiveKernel`;
* :class:`KernelReport` -- metrics summary + trace summary + hint counters
  in one JSON-serializable object, so drivers stop hand-assembling
  percentile dicts and print lines.
"""
from __future__ import annotations

import json
import math
from typing import Optional, Union

from .base import Policy, SchedCore
from .kernel import SchedKernel, SimExecutor
from .live import LiveKernel
from .metrics import Metrics
from .policies import make_policy
from .trace import SchedTracer

__all__ = ["build_kernel", "KernelReport"]

MODES = ("sim", "live")


def build_kernel(
    mode: str = "sim",
    *,
    policy: Union[str, Policy] = "ufs",
    n_slots: int = 1,
    kick_latency: float = 0.0,
    tracer: Optional[SchedTracer] = None,
    trace: bool = False,
    metrics: Optional[Metrics] = None,
    hints=None,
    hints_enabled: bool = True,
    seed: int = 0,
) -> SchedCore:
    """Build a scheduling kernel for either execution backend.

    ``policy`` is a registered policy name (``"ufs"``, ``"vdf"``, ...) or a
    :class:`Policy` instance.  ``trace=True`` attaches a fresh
    :class:`SchedTracer` when none is passed; the kernel's tracer is always
    reachable as ``kernel.tracer``.  ``seed`` only affects the sim backend.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}: expected one of {MODES}")
    if isinstance(policy, str):
        policy = make_policy(policy)
    if trace and tracer is None:
        tracer = SchedTracer()
    cls = SchedKernel if mode == "sim" else LiveKernel
    return cls(n_slots, policy, hints=hints, metrics=metrics,
               kick_latency=kick_latency, hints_enabled=hints_enabled,
               seed=seed, tracer=tracer)


def _finite(obj):
    """Recursively replace non-finite floats with None (strict-JSON safe)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _finite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_finite(v) for v in obj]
    return obj


class KernelReport:
    """Unified end-of-run telemetry: ``Metrics.summary`` + ``TraceSummary``
    + hint counters, with one ``to_json``.  Serve/train/benchmarks build
    this instead of hand-assembling final-stats dicts."""

    def __init__(self, mode: str, policy: str, n_slots: int,
                 metrics: dict, trace: Optional[dict] = None,
                 hints: Optional[dict] = None):
        self.mode = mode
        self.policy = policy
        self.n_slots = n_slots
        self.metrics = metrics
        self.trace = trace
        self.hints = hints or {}

    @classmethod
    def from_kernel(cls, kernel: SchedCore,
                    groups: Optional[list] = None) -> "KernelReport":
        mode = "sim" if isinstance(kernel.executor, SimExecutor) else "live"
        tracer = kernel.tracer
        return cls(
            mode=mode,
            policy=getattr(kernel.policy, "name", type(kernel.policy).__name__),
            n_slots=len(kernel.slots),
            metrics=kernel.metrics.summary(groups=groups,
                                           n_slots=len(kernel.slots)),
            trace=tracer.summary().to_dict() if tracer is not None else None,
            hints={"writes": kernel.hints.writes, "boosts": kernel.hints.boosts},
        )

    def to_dict(self) -> dict:
        return {"mode": self.mode, "policy": self.policy,
                "n_slots": self.n_slots, "metrics": self.metrics,
                "trace": self.trace, "hints": self.hints}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(_finite(self.to_dict()), sort_keys=True,
                          indent=indent, allow_nan=False)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    # ------------------------------------------------------------------
    def pretty(self) -> str:
        """A few human-readable lines for driver stdout."""
        c = self.metrics["counters"]
        lines = [f"[{self.mode}/{self.policy}] slots={self.n_slots} "
                 f"preemptions={c['preemptions']} kicks={c['kicks']} "
                 f"dispatches={c['dispatches']} "
                 f"hint_writes={self.hints.get('writes', 0)} "
                 f"boosts={self.hints.get('boosts', 0)}"]
        for g, row in sorted(self.metrics["groups"].items()):
            lat = row["latency"]
            lat_txt = ""
            if lat["n"]:
                lat_txt = (f"  lat mean {lat['mean']*1e3:.2f} ms "
                           f"p95 {lat['p95']*1e3:.2f} ms (n={lat['n']})")
            lines.append(f"  group {g}: completed={row['completed']} "
                         f"cpu={row['cpu_s']:.3f}s{lat_txt}")
        if self.trace is not None:
            lines.append(f"  trace: {self.trace['events']} events "
                         f"({self.trace['dropped']} dropped), "
                         f"{self.trace['inversions_resolved']}/"
                         f"{self.trace['inversions']} inversions resolved")
        return "\n".join(lines)
