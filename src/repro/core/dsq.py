"""Dispatch queues (paper section 2, "sched_ext" background).

* :class:`LocalDSQ` -- per-slot run queue holding jobs intended to run on that
  slot soon; ordered by a policy-provided key (vruntime for UFS, virtual
  deadline for the VDF baseline, FIFO order for RT baselines).
* :class:`GroupDSQ` -- custom per-group queue for deferred background
  dispatch; ordered by task virtual runtime.

Implementation: an indexed binary heap with lazy deletion (DESIGN.md
section 11).  Entries are mutable ``[key, tie, job]`` cells kept in a
``heapq`` heap plus a ``jid -> cell`` index, so the hot operations are

* ``push``            -- O(log n)
* ``pop_front``       -- amortized O(log n) (plus draining dead cells)
* ``remove``          -- O(1): mark the indexed cell dead, prune lazily
* ``peek_front/key``  -- amortized O(1)

``remove`` is the operation that matters: the hint-boost path pulls a lock
holder out of an arbitrarily deep background DSQ on *every* priority
inversion, which was O(n) per boost on the previous sorted-list layout and
dominated deep-queue sim time.  Dead cells are pruned at the heap top on
every peek/pop and compacted wholesale once they outnumber live ones.

The tie counter is **per queue** (not module-global): two kernels built in
the same process observe identical tie-break sequences, so same-seed runs
are byte-identical run to run.  Ties are unique within a queue, so heap
comparisons never reach the ``job`` field.
"""
from __future__ import annotations

import heapq
from typing import Optional

from .task import Job

_COMPACT_MIN_DEAD = 16   # never compact tiny queues


class _OrderedQueue:
    __slots__ = ("_heap", "_index", "_tie", "_dead")

    def __init__(self) -> None:
        self._heap: list = []          # [key, tie, job-or-None] cells
        self._index: dict = {}         # jid -> live cell
        self._tie = 0
        self._dead = 0                 # dead cells still sitting in _heap

    def __len__(self) -> int:
        return len(self._index)

    def __bool__(self) -> bool:
        return bool(self._index)

    # ------------------------------------------------------------ internals
    def _prune(self) -> None:
        """Drop dead cells off the heap top."""
        heap = self._heap
        while heap and heap[0][2] is None:
            heapq.heappop(heap)
            self._dead -= 1

    def _compact(self) -> None:
        if self._dead > _COMPACT_MIN_DEAD and self._dead * 2 > len(self._heap):
            self._heap = [c for c in self._heap if c[2] is not None]
            heapq.heapify(self._heap)
            self._dead = 0

    # ------------------------------------------------------------- hot path
    def push(self, job: Job, key: float) -> None:
        old = self._index.get(job.jid)
        if old is not None:            # double-push: supersede the stale cell
            old[2] = None
            self._dead += 1
        self._tie += 1
        cell = [key, self._tie, job]
        self._index[job.jid] = cell
        heapq.heappush(self._heap, cell)

    def pop_front(self) -> Optional[Job]:
        self._prune()
        if not self._heap:
            return None
        key, tie, job = heapq.heappop(self._heap)
        del self._index[job.jid]
        return job

    def peek_front(self) -> Optional[Job]:
        self._prune()
        return self._heap[0][2] if self._heap else None

    def peek_key(self) -> Optional[float]:
        self._prune()
        return self._heap[0][0] if self._heap else None

    def remove(self, job: Job) -> bool:
        """Keyed removal: O(1) dead-marking via the jid index."""
        cell = self._index.get(job.jid)
        if cell is None or cell[2] is not job:
            return False
        cell[2] = None
        del self._index[job.jid]
        self._dead += 1
        self._compact()
        return True

    # ----------------------------------------------------------- cold path
    def pop_back(self) -> Optional[Job]:
        """O(n): the heap has no cheap max.  Only used by tests/tools."""
        if not self._index:
            return None
        cell = max(self._index.values())
        cell_job = cell[2]
        cell[2] = None
        del self._index[cell_job.jid]
        self._dead += 1
        self._compact()
        return cell_job

    def pop_first_where(self, pred) -> Optional[Job]:
        """Pop the first job (in key order) satisfying ``pred``.

        Pops cells off the heap while scanning and re-pushes the skipped
        ones afterwards; since cells keep their (key, tie), order is
        preserved exactly.  ``pred`` raising never loses entries.
        """
        heap = self._heap
        skipped: list = []
        found: Optional[Job] = None
        try:
            while heap:
                cell = heapq.heappop(heap)
                job = cell[2]
                if job is None:
                    self._dead -= 1
                    continue
                skipped.append(cell)     # keep provisionally: pred may raise
                if pred(job):
                    skipped.pop()
                    del self._index[job.jid]
                    found = job
                    break
        finally:
            for cell in skipped:
                heapq.heappush(heap, cell)
        return found

    def jobs(self) -> list:
        """Live jobs in key order (O(n log n); reporting/balancing only)."""
        return [c[2] for c in sorted(self._index.values())]

    def total_key_weight(self, keyfn) -> float:
        # Summed in key order so float accumulation matches the old sorted
        # layout bit for bit.
        return sum(keyfn(j) for j in self.jobs())


class LocalDSQ(_OrderedQueue):
    """Per-slot local dispatch queue."""
    __slots__ = ()


class GroupDSQ(_OrderedQueue):
    """Per-group custom dispatch queue, ordered by task vruntime: the task at
    the head has executed the least and runs first (paper section 5.1.3)."""
    __slots__ = ()
