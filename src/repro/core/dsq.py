"""Dispatch queues (paper section 2, "sched_ext" background).

* :class:`LocalDSQ` -- per-slot run queue holding jobs intended to run on that
  slot soon; ordered by a policy-provided key (vruntime for UFS, virtual
  deadline for the VDF baseline, FIFO order for RT baselines).
* :class:`GroupDSQ` -- custom per-group queue for deferred background
  dispatch; ordered by task virtual runtime.

Both are small ordered containers with O(log n) insert and O(1)/O(log n) pop;
``bisect`` on a list is ideal at the queue sizes a slot or group ever holds.
"""
from __future__ import annotations

import bisect
import itertools
from typing import Optional

from .task import Job

_tie = itertools.count()


class _OrderedQueue:
    def __init__(self) -> None:
        self._items: list[tuple[float, int, Job]] = []

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def push(self, job: Job, key: float) -> None:
        bisect.insort(self._items, (key, next(_tie), job))

    def pop_front(self) -> Optional[Job]:
        if not self._items:
            return None
        return self._items.pop(0)[2]

    def peek_front(self) -> Optional[Job]:
        return self._items[0][2] if self._items else None

    def peek_key(self) -> Optional[float]:
        return self._items[0][0] if self._items else None

    def pop_back(self) -> Optional[Job]:
        if not self._items:
            return None
        return self._items.pop()[2]

    def pop_first_where(self, pred) -> Optional[Job]:
        for i, (_, _, j) in enumerate(self._items):
            if pred(j):
                del self._items[i]
                return j
        return None

    def remove(self, job: Job) -> bool:
        for i, (_, _, j) in enumerate(self._items):
            if j is job:
                del self._items[i]
                return True
        return False

    def jobs(self) -> list[Job]:
        return [j for _, _, j in self._items]

    def total_key_weight(self, keyfn) -> float:
        return sum(keyfn(j) for _, _, j in self._items)


class LocalDSQ(_OrderedQueue):
    """Per-slot local dispatch queue."""


class GroupDSQ(_OrderedQueue):
    """Per-group custom dispatch queue, ordered by task vruntime: the task at
    the head has executed the least and runs first (paper section 5.1.3)."""
