"""Experiment harness: builds the paper's workload mixes against any policy.

Mirrors Table 1 / Table 2 of the paper:

* SOLO      -- N bursty workers alone (or N bound workers alone)
* MIN:MAX   -- bursty at maximum priority, bound at minimum
* 50:50     -- both at the same (high) priority

Weights per Table 2 / section 6: high = 10k, low = 1. Under UFS the
low-priority work lives in a background-tier group; under the baselines the
tier merely selects the scheduling class per Table 2 (RT vs normal, idle).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .build import build_kernel
from .metrics import Metrics
from .task import Job, Tier
from .trace import SchedTracer
from . import workloads as wl

HIGH_WEIGHT = 10_000.0
LOW_WEIGHT = 1.0


@dataclass
class MixResult:
    policy: str
    metrics: Metrics
    n_slots: int
    duration: float
    _summary: Optional[dict] = field(default=None, repr=False, compare=False)

    def summary(self) -> dict:
        """The unified ``Metrics.summary`` view (computed once)."""
        if self._summary is None:
            self._summary = self.metrics.summary(n_slots=self.n_slots)
        return self._summary

    def thr(self, group: str) -> float:
        row = self.summary()["groups"].get(group)
        return row["throughput"] if row else 0.0

    def lat(self, group: str) -> dict:
        row = self.summary()["groups"].get(group)
        return row["latency"] if row else self.metrics.latency_stats(group)


def run_mix(
    policy_name: str,
    n_slots: int = 8,
    n_bursty: int = 8,
    n_bound: int = 8,
    bound_tier: Tier = Tier.BACKGROUND,
    bound_weight: float = LOW_WEIGHT,
    bursty_weight: float = HIGH_WEIGHT,
    duration: float = 60.0,
    warmup: float = 60.0,
    seed: int = 0,
    hints_enabled: bool = True,
    bursty_groups: Optional[list] = None,   # [(name, weight, n), ...] overrides
    bound_groups: Optional[list] = None,
    query_cpu: float = wl.QUERY_CPU,
    kick_latency: float = 0.0,
    n_rx_slots: int = 1,
    tracer: Optional[SchedTracer] = None,
) -> MixResult:
    """Run one workload mix for ``duration`` seconds after ``warmup``.

    ``n_rx_slots`` models how many slots take network-RX interrupts (the
    wakeup source for client-driven bursty backends); wake-affine placement
    in the VDF baseline gravitates wakees toward these slots.  Pass a
    :class:`SchedTracer` to capture the run's scheduling events.
    """
    kernel = build_kernel("sim", policy=policy_name, n_slots=n_slots,
                          hints_enabled=hints_enabled,
                          kick_latency=kick_latency, tracer=tracer, seed=seed)

    if bursty_groups is None:
        bursty_groups = [("ts", bursty_weight, n_bursty)]
    if bound_groups is None:
        bound_groups = [("bg", bound_weight, n_bound)]

    jid = 0
    for gname, weight, n in bursty_groups:
        if n == 0:
            continue
        g = kernel.create_group(gname, Tier.TIME_SENSITIVE, weight)
        for i in range(n):
            job = Job(g, behavior=wl.bursty_worker(seed * 1000 + jid),
                      name=f"{gname}-{i}", kind="bursty")
            job.waker_slot = jid % max(1, n_rx_slots)
            kernel.add_job(job, at=0.0)
            jid += 1
    for gname, weight, n in bound_groups:
        if n == 0:
            continue
        g = kernel.create_group(gname, bound_tier, weight)
        for i in range(n):
            job = Job(g, behavior=wl.bound_worker(seed * 1000 + jid, query_cpu=query_cpu),
                      name=f"{gname}-{i}", kind="bound")
            kernel.add_job(job, at=0.0)
            jid += 1

    metrics = kernel.run(warmup + duration, warmup=warmup)
    return MixResult(policy_name, metrics, n_slots, duration)


def scenario(policy: str, mix: str, n_slots: int = 8, n: int = 8,
             duration: float = 60.0, warmup: float = 60.0, seed: int = 0,
             **kw) -> MixResult:
    """Named scenarios from Table 1."""
    if mix == "solo":
        return run_mix(policy, n_slots, n_bursty=n, n_bound=0,
                       duration=duration, warmup=warmup, seed=seed, **kw)
    if mix == "solo_bound":
        return run_mix(policy, n_slots, n_bursty=0, n_bound=n,
                       duration=duration, warmup=warmup, seed=seed, **kw)
    if mix == "minmax":
        return run_mix(policy, n_slots, n_bursty=n, n_bound=n,
                       bound_tier=Tier.BACKGROUND, bound_weight=LOW_WEIGHT,
                       duration=duration, warmup=warmup, seed=seed, **kw)
    if mix == "5050":
        return run_mix(policy, n_slots, n_bursty=n, n_bound=n,
                       bound_tier=Tier.TIME_SENSITIVE, bound_weight=HIGH_WEIGHT,
                       duration=duration, warmup=warmup, seed=seed, **kw)
    raise ValueError(f"unknown mix {mix!r}")
