"""Deterministic fault injection for the scheduling core (DESIGN.md
section 12).

The paper's UFS argument is that background work can never hurt
time-sensitive work; that only holds if it survives jobs that *misbehave*.
This module is the crash-injection harness the containment tests drive:
deterministic injectors (counter-triggered, no randomness, no timing
dependence) usable from **both** backends --

* sim: a behaviour generator raises mid-phase
  (:func:`crashy_behavior`, :func:`crashing_holder`), the analogue of a
  backend process dying;
* live: a ``run_chunk`` callable raises (:func:`crashing_chunk`), or a
  side thread occupies a :class:`~repro.core.live.LiveLock` so an
  ``acquire`` deterministically times out (:func:`occupy_lock`);
* either: a deferred :func:`drain_after` takes a slot offline mid-run.

Every injector funnels into the one panic path
(:meth:`~repro.core.base.SchedCore.panic_job`), so the failure modes the
tests exercise are exactly the ones production would take.
"""
from __future__ import annotations

import threading
from collections import Counter
from typing import Callable, Iterable, Optional

from .task import AcquireLock, Burst, Job, Phase, ReleaseLock


class FaultInjected(RuntimeError):
    """Raised by injectors at their trigger point."""


class FaultInjector:
    """Counter-triggered fault plan: ``plan`` maps site name -> the hit
    number (1-based) at which that site fires.  ``repeat`` makes a site
    fire on every hit at or past its trigger (crash loops); the default
    fires exactly once.

    >>> inj = FaultInjector({"chunk": 3})
    >>> [inj.fires("chunk") for _ in range(4)]
    [False, False, True, False]
    """

    def __init__(self, plan: Optional[dict] = None, repeat: bool = False):
        self.plan = dict(plan or {})
        self.repeat = repeat
        self.hits: Counter = Counter()
        self.fired: Counter = Counter()
        self._mu = threading.Lock()       # live chunks hit from worker threads

    def fires(self, site: str) -> bool:
        """Count a hit at ``site``; True when the plan says to fail."""
        with self._mu:
            self.hits[site] += 1
            at = self.plan.get(site)
            if at is None:
                return False
            n = self.hits[site]
            if n == at or (self.repeat and n > at):
                self.fired[site] += 1
                return True
            return False

    def check(self, site: str, exc: type = FaultInjected) -> None:
        """Raise ``exc`` when the plan fires at ``site``."""
        if self.fires(site):
            raise exc(f"injected fault at {site!r} (hit {self.hits[site]})")


# ---------------------------------------------------------------------------
# Live-backend injectors
# ---------------------------------------------------------------------------

def crashing_chunk(injector: FaultInjector, site: str = "chunk",
                   inner: Optional[Callable[[float], str]] = None,
                   ) -> Callable[[float], str]:
    """Wrap a live ``run_chunk`` so it raises when the injector fires.
    Without ``inner``, the chunk yields until the trigger point."""
    def chunk(budget: float) -> str:
        injector.check(site)
        return inner(budget) if inner is not None else "yield"
    return chunk


def occupy_lock(lock, job: Job, until: Optional[threading.Event] = None,
                ) -> threading.Event:
    """Acquire a :class:`~repro.core.live.LiveLock` as ``job`` from a side
    thread and hold it until the returned event is set -- the
    deterministic driver for the ``acquire``-timeout path.  The acquire
    has happened by the time this returns."""
    release = until or threading.Event()
    held = threading.Event()

    def holder() -> None:
        lock.acquire(job)
        held.set()
        release.wait()
        lock.release(job)

    t = threading.Thread(target=holder, daemon=True)
    t.start()
    held.wait()
    return release


# ---------------------------------------------------------------------------
# Sim-backend injectors
# ---------------------------------------------------------------------------

def crashy_behavior(injector: FaultInjector, phases: Iterable[Phase],
                    site: str = "chunk"):
    """Yield ``phases``, consulting the injector before each -- the sim
    analogue of a chunk crash: the generator raises mid-stream and the
    phase machinery routes it to the panic path."""
    for ph in phases:
        injector.check(site)
        yield ph


def crashing_holder(lock, hold_cpu: float = 1e-3,
                    crash: bool = True) -> Callable[[], object]:
    """Behaviour *factory* (suitable for ``Job(behavior_factory=...)``, so
    retries rebuild it): acquire ``lock``, burn ``hold_cpu``, then raise
    while still holding it.  ``crash=False`` yields a well-behaved control
    run of the same shape."""
    def behavior():
        yield AcquireLock(lock)
        yield Burst(hold_cpu)
        if crash:
            raise FaultInjected(f"crash while holding {lock.name}")
        yield ReleaseLock(lock)
    return behavior


# ---------------------------------------------------------------------------
# Backend-agnostic injectors
# ---------------------------------------------------------------------------

def drain_after(kernel, sid: int, delay: float) -> None:
    """Take slot ``sid`` offline after ``delay`` on the kernel's clock
    (virtual or monotonic): slot-loss injection mid-run on either backend."""
    kernel.executor.defer(delay, lambda: kernel.drain_slot(sid))
