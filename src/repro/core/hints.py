"""Application-based scheduler hinting (paper sections 4, 5.2).

The eBPF-map analogue: a shared table the application (engine) writes lock
events into and the scheduler reads when making decisions. Each entry pairs
(job id, lock id), mirroring the paper's map entries of (PID, lock id).

The scheduler reacts on the *wait-start* path: when a time-sensitive job
reports waiting on a lock currently held by a background job, the holder is
temporarily **boosted** into the time-sensitive tier until it releases the
lock -- resolving indirect priority inversion. Boosting is reference-counted
per held lock so nested locks behave.

All operations are O(1) dict updates; the overhead benchmark
(benchmarks/sec67_hint_overhead.py) reproduces the paper's <=1% finding.
"""
from __future__ import annotations

from typing import Callable, Optional

from .task import Job, Tier


class HintTable:
    """Shared app<->scheduler hint state (eBPF map analogue)."""

    def __init__(self) -> None:
        self.holders: dict[int, Job] = {}          # lock_id -> holder job
        self.waiters: dict[int, list[Job]] = {}    # lock_id -> waiting jobs
        self._boost_reasons: dict[int, set[int]] = {}  # holder jid -> {lock_id}
        # Scheduler callbacks, wired by the policy at attach time.
        self.on_boost: Optional[Callable[[Job], None]] = None
        self.on_unboost: Optional[Callable[[Job], None]] = None
        # Metrics
        self.writes = 0
        self.boosts = 0

    # ------------------------------------------------------------------ app side
    def report_lock_acquired(self, job: Job, lock_id: int) -> None:
        self.writes += 1
        self.holders[lock_id] = job
        # A holder that someone already waits on (race: waiter registered
        # between release and re-acquire) may need an immediate boost.
        self._maybe_boost(lock_id)

    def report_wait_start(self, job: Job, lock_id: int) -> None:
        """pgstat_report_wait_start analogue (idempotent per waiter)."""
        self.writes += 1
        w = self.waiters.setdefault(lock_id, [])
        if job not in w:
            w.append(job)
        self._maybe_boost(lock_id)

    def report_wait_end(self, job: Job, lock_id: int) -> None:
        """pgstat_report_wait_end analogue.

        Also re-evaluates the holder's boost: a waiter that leaves
        *without* acquiring (acquire timeout, panic) may have been the
        time-sensitive waiter the boost exists for, and the boost must
        expire with the wait, not with the lock -- otherwise a timed-out
        waiter leaves the holder boosted indefinitely."""
        self.writes += 1
        w = self.waiters.get(lock_id)
        if w and job in w:
            w.remove(job)
            if not w:
                del self.waiters[lock_id]
            self._reevaluate(lock_id)

    def report_lock_released(self, job: Job, lock_id: int) -> None:
        self.writes += 1
        if self.holders.get(lock_id) is job:
            del self.holders[lock_id]
        self._unboost(job, lock_id)

    def purge_job(self, job: Job) -> None:
        """Remove every trace of ``job`` from the table (panic/quarantine
        containment, DESIGN.md section 12): wait entries it would otherwise
        leak, its own boost residue, and any holder entries still naming it
        after its locks were force-released outside the normal path.  Boosts
        other holders carry on this job's behalf are re-evaluated so they
        expire with the dead waiter."""
        for lock_id in [lid for lid, w in self.waiters.items() if job in w]:
            self.report_wait_end(job, lock_id)
        reasons = self._boost_reasons.pop(job.jid, None)
        if reasons and job.boosted:
            job.boosted = False
            job.boost_group = None
            if self.on_unboost is not None:
                self.on_unboost(job)
        for lock_id in [lid for lid, h in self.holders.items() if h is job]:
            del self.holders[lock_id]

    # ------------------------------------------------------------ scheduler side
    def _maybe_boost(self, lock_id: int) -> None:
        holder = self.holders.get(lock_id)
        if holder is None or holder.group.tier != Tier.BACKGROUND:
            return
        waiters = self.waiters.get(lock_id, ())
        ts_waiter = next((w for w in waiters if w.tier == Tier.TIME_SENSITIVE), None)
        if ts_waiter is None:
            return
        reasons = self._boost_reasons.setdefault(holder.jid, set())
        if lock_id in reasons:
            return
        reasons.add(lock_id)
        if not holder.boosted:
            holder.boosted = True
            # Priority inheritance: schedule the holder as a member of the
            # waiting time-sensitive task's group until release.
            holder.boost_group = ts_waiter.sched_group()
            holder.boost_count += 1
            self.boosts += 1
            if self.on_boost is not None:
                self.on_boost(holder)

    def _reevaluate(self, lock_id: int) -> None:
        """A waiter left without acquiring: if no time-sensitive waiter
        remains, retract the holder's boost reason for this lock.  On the
        normal hand-off path the releasing holder is already gone from
        ``holders`` by the time the new owner reports wait-end, so this is
        a no-op there."""
        holder = self.holders.get(lock_id)
        if holder is None:
            return
        waiters = self.waiters.get(lock_id, ())
        if any(w.tier == Tier.TIME_SENSITIVE for w in waiters):
            return
        self._unboost(holder, lock_id)

    def _unboost(self, holder: Job, lock_id: int) -> None:
        reasons = self._boost_reasons.get(holder.jid)
        if not reasons:
            return
        reasons.discard(lock_id)
        if not reasons and holder.boosted:
            holder.boosted = False
            holder.boost_group = None
            del self._boost_reasons[holder.jid]
            if self.on_unboost is not None:
                self.on_unboost(holder)
