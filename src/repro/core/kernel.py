"""The scheduling kernel: slots, event loop, dispatch/preemption machinery.

This is the host-side analogue of the kernel scheduling core that
``sched_ext`` policies plug into (DESIGN.md section 2). It owns:

* **slots** -- execution units (device slots on a pod; CPUs in the paper),
  each with a local DSQ;
* the **event loop** -- a deterministic discrete-event clock in sim mode
  (benchmarks reproduce the paper's experiments in virtual time); live mode
  (``repro.serving.live``) drives the same policy objects with real threads;
* the callback surface policies implement (:class:`Policy`), mirroring
  sched_ext's ``select_cpu / enqueue / dispatch / running / stopping``;
* preemption **kicks**, job lifecycle, lock parking/spinning, hint wiring.

Policies never advance time themselves; they only mutate queue state and
request kicks, exactly as eBPF callbacks do.
"""
from __future__ import annotations

import heapq
import itertools
from abc import ABC, abstractmethod
from typing import Callable, Optional

from .dsq import GroupDSQ, LocalDSQ
from .hints import HintTable
from .locks import SimLock
from .metrics import Metrics
from .task import (AcquireLock, Block, Burst, Exit, Job, JobState, PanicExit,
                   ReleaseLock, RequestBegin, RequestEnd, Tier, TryLock,
                   WorkloadGroup)

DEFAULT_SLICE = 0.003  # 3 ms bounded execution interval (paper section 5.1.1)


class SimClock:
    """Deterministic discrete-event clock: heap of (time, seq, fn)."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: list = []
        self._seq = itertools.count()

    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run_until(self, horizon: float) -> None:
        while self._heap and self._heap[0][0] <= horizon:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.now = max(self.now, horizon)

    def empty(self) -> bool:
        return not self._heap


class Slot:
    """An execution unit: one mesh-slice program context (a CPU, in the paper)."""

    def __init__(self, sid: int):
        self.sid = sid
        self.local_dsq = LocalDSQ()
        self.current: Optional[Job] = None
        self.run_token = 0            # invalidates stale run-end events
        self.run_started = 0.0
        self.slice_budget = 0.0
        self.online = True            # False once drained (elasticity)
        self.dl_served_until = 0.0    # fair-server window (RT baselines)
        self.rt_window_start = 0.0    # RT-throttling accounting
        self.rt_window_usage = 0.0

    @property
    def idle(self) -> bool:
        return self.current is None and len(self.local_dsq) == 0

    def __repr__(self) -> str:  # pragma: no cover
        cur = self.current.name if self.current else "-"
        return f"Slot({self.sid}, cur={cur}, q={len(self.local_dsq)})"


class Policy(ABC):
    """sched_ext-style policy callback surface."""

    name = "abstract"

    def attach(self, kernel: "SchedKernel") -> None:
        self.kernel = kernel

    @abstractmethod
    def enqueue(self, job: Job, requeue: bool = False) -> None:
        """Job became runnable (wakeup) or must be requeued (preempt/slice)."""

    @abstractmethod
    def dispatch(self, slot: Slot) -> None:
        """Slot needs work and its local DSQ is empty: pull if possible."""

    def pick_next(self, slot: Slot):
        """Select the next job for a free slot: local DSQ first, then pull
        via :meth:`dispatch`. Policies may override the pick order (e.g. the
        RT fair-server window)."""
        nxt = slot.local_dsq.pop_front()
        while nxt is not None and nxt.state != JobState.RUNNABLE:
            nxt = slot.local_dsq.pop_front()
        if nxt is None:
            self.kernel.metrics.dispatches += 1
            self.dispatch(slot)
            nxt = slot.local_dsq.pop_front()
            while nxt is not None and nxt.state != JobState.RUNNABLE:
                nxt = slot.local_dsq.pop_front()
        return nxt

    def running(self, job: Job, slot: Slot) -> None:
        """Job starts executing on slot."""

    def stopping(self, job: Job, slot: Slot, used: float) -> None:
        """Job stops executing (block/preempt/slice/exit); charge service."""

    def task_slice(self, job: Job) -> float:
        return DEFAULT_SLICE

    def on_boost(self, job: Job) -> None:
        """Hint boost fired for a queued/running background job."""

    def on_unboost(self, job: Job) -> None:
        pass

    def periodic(self) -> None:
        """Optional periodic work (load balancing); driven by kernel timer."""

    periodic_interval: Optional[float] = None


class SchedKernel:
    """Sim-mode scheduling kernel."""

    def __init__(
        self,
        n_slots: int,
        policy: Policy,
        hints: Optional[HintTable] = None,
        metrics: Optional[Metrics] = None,
        kick_latency: float = 0.0,
        hints_enabled: bool = True,
        seed: int = 0,
    ):
        self.clock = SimClock()
        self.slots = [Slot(i) for i in range(n_slots)]
        self.policy = policy
        self.hints = hints or HintTable()
        self.hints_enabled = hints_enabled
        self.metrics = metrics or Metrics()
        self.kick_latency = kick_latency
        self.jobs: dict[int, Job] = {}
        self.groups: dict[str, WorkloadGroup] = {}
        self._rng_state = seed or 1
        self.on_panic: Optional[Callable[[Job], None]] = None
        policy.attach(self)
        self.hints.on_boost = self._hint_boost
        self.hints.on_unboost = self._hint_unboost
        if policy.periodic_interval:
            self._schedule_periodic()

    # ------------------------------------------------------------- utilities
    @property
    def now(self) -> float:
        return self.clock.now

    def create_group(self, name: str, tier: Tier, weight: float = 100.0,
                     parent: Optional[WorkloadGroup] = None, **kw) -> WorkloadGroup:
        g = WorkloadGroup(name, tier, weight, parent=parent, **kw)
        g.dsq = GroupDSQ()          # custom DSQ (background deferred dispatch)
        self.groups[name] = g
        return g

    def create_lock(self, name: str = "") -> SimLock:
        return SimLock(self, name)

    def online_slots(self) -> list:
        return [s for s in self.slots if s.online]

    # ------------------------------------------------------------ job control
    def add_job(self, job: Job, at: float = 0.0) -> None:
        self.jobs[job.jid] = job
        self.clock.at(at, lambda: self._advance(job))

    def run(self, horizon: float, warmup: float = 0.0) -> Metrics:
        self.metrics.window_start = warmup
        self.metrics.window_end = horizon
        self.clock.run_until(horizon)
        self._settle_accounting()
        return self.metrics

    def _settle_accounting(self) -> None:
        """Charge partially-elapsed runs at the horizon so utilization sums."""
        for slot in self.slots:
            job = slot.current
            if job is not None:
                used = self.now - slot.run_started
                if used > 0:
                    self.metrics.record_run(slot.sid, job.kind, job.group.name, used, self.now)
                    slot.run_started = self.now

    # ------------------------------------------------------------- scheduling
    def wake(self, job: Job) -> None:
        """Job becomes runnable; hand to the policy's enqueue path."""
        if job.state == JobState.EXITED:
            return
        job.state = JobState.RUNNABLE
        job.wakeup_time = self.now
        job.location = None
        self.policy.enqueue(job, requeue=False)

    def requeue(self, job: Job) -> None:
        job.state = JobState.RUNNABLE
        job.location = None
        self.policy.enqueue(job, requeue=True)

    def kick(self, slot: Slot, preempt: bool = False) -> None:
        """Wake an idle slot, or (preempt=True) force the running job off.

        ``kick_latency`` models the TPU chunk-boundary adaptation: a kick
        takes effect only once the in-flight device program retires.
        """
        self.metrics.kicks += 1
        if self.kick_latency > 0:
            self.clock.after(self.kick_latency, lambda: self._kick_now(slot, preempt))
        else:
            self._kick_now(slot, preempt)

    def _kick_now(self, slot: Slot, preempt: bool) -> None:
        if not slot.online:
            return
        if slot.current is None:
            self._schedule_next(slot)
        elif preempt:
            self._preempt(slot)

    def _preempt(self, slot: Slot) -> None:
        job = slot.current
        if job is None:
            return
        self.metrics.preemptions += 1
        used = self.now - slot.run_started
        self._stop_current(slot, used)
        job.burst_remaining -= used
        if job.burst_remaining <= 1e-12:
            # Raced with burst completion; let the phase machine finish it.
            job.burst_remaining = 0.0
            self._advance(job)
        else:
            self.requeue(job)
        self._schedule_next(slot)

    def _stop_current(self, slot: Slot, used: float) -> None:
        job = slot.current
        assert job is not None
        slot.run_token += 1                      # cancel in-flight run-end event
        self.policy.stopping(job, slot, used)
        self.metrics.record_run(slot.sid, job.kind, job.group.name, used, self.now)
        slot.current = None

    def _schedule_next(self, slot: Slot) -> None:
        if not slot.online or slot.current is not None:
            return
        nxt = self.policy.pick_next(slot)
        if nxt is None:
            return                               # idle
        self._start(slot, nxt)

    def _start(self, slot: Slot, job: Job) -> None:
        assert job.state == JobState.RUNNABLE, f"{job} not runnable"
        job.state = JobState.RUNNING
        job.location = None
        if job.wakeup_time >= 0.0:
            self.metrics.record_wakeup(job.group.name, self.now - job.wakeup_time, self.now)
            job.wakeup_time = -1.0               # record only first start per wake
        job.prev_slot = slot.sid
        slot.current = job
        slot.run_started = self.now
        slot.slice_budget = self.policy.task_slice(job)
        self.policy.running(job, slot)
        self._arm_run_end(slot)

    def _arm_run_end(self, slot: Slot) -> None:
        job = slot.current
        run_for = min(job.burst_remaining, slot.slice_budget)
        slot.run_token += 1
        token = slot.run_token
        self.clock.after(run_for, lambda: self._run_end(slot, token))

    def _run_end(self, slot: Slot, token: int) -> None:
        if token != slot.run_token or slot.current is None:
            return                               # stale event (preempted meanwhile)
        job = slot.current
        used = self.now - slot.run_started
        job.burst_remaining -= used
        if job.burst_remaining <= 1e-12:
            job.burst_remaining = 0.0
            self._stop_current(slot, used)
            self._advance(job, from_slot=slot)
            self._schedule_next(slot)
        else:
            # Slice expiry: charge, requeue, pick next (paper: re-enqueue path).
            self._stop_current(slot, used)
            self.requeue(job)
            self._schedule_next(slot)

    # ------------------------------------------------------- phase machinery
    def _advance(self, job: Job, from_slot: Optional[Slot] = None) -> None:
        """Drive the job's behaviour generator until it needs CPU or sleeps.

        Phases are advanced with ``generator.send(resume_value)`` so that
        zero-time probes (``TryLock``) can return results into the workload
        generator (spin-acquire loops, see ``core.locks.spin_acquire``).
        """
        if job.state == JobState.EXITED:
            return
        while True:
            try:
                # send(None) on a fresh generator is next(); resume_value is
                # only ever non-None after the generator has started.
                value, job.resume_value = job.resume_value, None
                ph = job.behavior.send(value)
            except StopIteration:
                self._exit(job)
                return
            if isinstance(ph, Burst):
                job.burst_remaining = ph.duration
                job.current_request = ph.request_id
                if from_slot is not None and from_slot.online:
                    # Back-to-back burst (no voluntary sleep): requeue, not
                    # wakeup -- no sleeper credit, and the policy decides
                    # whether the job keeps the slot (FIFO front / vruntime
                    # order / fair-server window all apply here).
                    job.state = JobState.RUNNABLE
                    job.wakeup_time = -1.0
                    self.requeue(job)
                    self._schedule_next(from_slot)
                else:
                    self.wake(job)
                return
            elif isinstance(ph, Block):
                job.state = JobState.BLOCKED
                self.clock.after(ph.duration, lambda j=job: self._advance(j))
                return
            elif isinstance(ph, TryLock):
                job.resume_value = ph.lock.try_acquire(job)
            elif isinstance(ph, RequestBegin):
                job.request_started_at = self.now
            elif isinstance(ph, RequestEnd):
                job.completed_requests += 1
                self.metrics.record_request(
                    job.group.name, self.now - job.request_started_at, self.now)
            elif isinstance(ph, AcquireLock):
                lock: SimLock = ph.lock
                if lock.try_acquire(job):
                    job.resume_value = True
                    continue
                lock.park(job)
                job.state = JobState.LOCK_WAIT       # parked; release hands off
                return
            elif isinstance(ph, ReleaseLock):
                woken = ph.lock.release(job)
                if woken is not None:
                    woken.resume_value = True
                    self._advance(woken)             # hand-off: waiter proceeds
            elif isinstance(ph, PanicExit):
                job.panic = True
                self.metrics.panics.append(job.name)
                if self.on_panic is not None:
                    self.on_panic(job)
                self._exit(job)
                return
            elif isinstance(ph, Exit):
                self._exit(job)
                return
            else:
                raise TypeError(f"unknown phase {ph!r}")

    def _exit(self, job: Job) -> None:
        job.state = JobState.EXITED
        for lock in list(job.held_locks):
            lock.release(job)

    # ----------------------------------------------------------- hint wiring
    def _hint_boost(self, job: Job) -> None:
        self.policy.on_boost(job)

    def _hint_unboost(self, job: Job) -> None:
        self.policy.on_unboost(job)

    # ----------------------------------------------------------- elasticity
    def add_slot(self) -> Slot:
        slot = Slot(len(self.slots))
        self.slots.append(slot)
        self.clock.after(0.0, lambda: self._schedule_next(slot))
        return slot

    def drain_slot(self, sid: int) -> None:
        """Take a slot offline: requeue its work elsewhere (node failure /
        elastic downscale)."""
        slot = self.slots[sid]
        slot.online = False
        if slot.current is not None:
            self._preempt(slot)
        while True:
            job = slot.local_dsq.pop_front()
            if job is None:
                break
            self.requeue(job)

    # ------------------------------------------------------------- periodic
    def _schedule_periodic(self) -> None:
        interval = self.policy.periodic_interval
        def tick() -> None:
            self.policy.periodic()
            self.clock.after(interval, tick)
        self.clock.after(interval, tick)
