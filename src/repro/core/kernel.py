"""Sim-mode execution backend: the discrete-event clock behind SchedCore.

This is the host-side analogue of the kernel scheduling core that
``sched_ext`` policies plug into (DESIGN.md section 2).  The shared
scheduling machinery -- slots, group/job registries, the policy callback
surface, enqueue/dispatch/start/stop/preempt, hint wiring -- lives in
:mod:`repro.core.base` (:class:`~repro.core.base.SchedCore`) and is common
to both execution modes.  This module contributes the **sim** backend:

* :class:`SimClock` -- a deterministic discrete-event clock (heap of
  timestamped callbacks); benchmarks reproduce the paper's experiments in
  virtual time;
* :class:`SimExecutor` -- drives generator-based :class:`Job` behaviours
  (bursts, blocks, lock phases) against the core: arms run-end events,
  applies burst accounting on preemption, advances the phase machinery;
* :class:`SchedKernel` -- the sim facade over :class:`SchedCore`
  (``add_job`` / ``run`` / ``create_lock``).

Live mode (``repro.core.live``) drives the *same* policy objects and the
same core with real threads.  Policies never advance time themselves; they
only mutate queue state and request kicks, exactly as eBPF callbacks do.
"""
from __future__ import annotations

import heapq
import traceback
import warnings
from contextlib import nullcontext
from typing import Callable, ContextManager, Optional

from .base import DEFAULT_SLICE, Executor, Policy, SchedCore, Slot
from .hints import HintTable
from .locks import SimLock
from .metrics import Metrics
from .trace import SchedTracer
from .task import (AcquireLock, Block, Burst, Exit, Job, JobState, PanicExit,
                   ReleaseLock, RequestBegin, RequestEnd, TryLock)

__all__ = ["SimClock", "SimExecutor", "SchedKernel", "Policy", "Slot",
           "SchedCore", "Executor", "DEFAULT_SLICE"]

_NULL_GUARD = nullcontext()


class SimClock:
    """Deterministic discrete-event clock with cancellable events.

    Events are mutable ``[time, seq, fn]`` cells; :meth:`at`/:meth:`after`
    return the cell as a handle and :meth:`cancel` kills it in O(1) by
    nulling ``fn`` (lazy deletion, DESIGN.md section 11).  Dead cells are
    skipped on pop and compacted wholesale once they outnumber live ones,
    so the heap no longer grows with every preemption or slice expiry.
    ``seq`` is per-clock, so same-seed runs are deterministic regardless of
    how many other kernels the process has built.

    :attr:`processed` counts executed events -- the denominator of the
    events/sec figure in ``benchmarks/microbench.py``.
    """

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: list = []          # [t, seq, fn-or-None] cells
        self._seq = 0
        self._dead = 0                 # cancelled cells still in the heap
        self.processed = 0

    def __len__(self) -> int:
        """Live (uncancelled) pending events."""
        return len(self._heap) - self._dead

    @property
    def heap_size(self) -> int:
        """Raw heap occupancy including dead cells (compaction telemetry)."""
        return len(self._heap)

    def at(self, t: float, fn: Callable[[], None]) -> list:
        self._seq += 1
        ev = [max(t, self.now), self._seq, fn]
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, dt: float, fn: Callable[[], None]) -> list:
        return self.at(self.now + dt, fn)

    def cancel(self, ev: list) -> bool:
        """Cancel a pending event.  Returns False if it already ran or was
        already cancelled.  O(1); the cell is pruned from the heap lazily."""
        if ev[2] is None:
            return False
        ev[2] = None
        self._dead += 1
        if self._dead > 64 and self._dead * 2 > len(self._heap):
            self._heap = [e for e in self._heap if e[2] is not None]
            heapq.heapify(self._heap)
            self._dead = 0
        return True

    def run_until(self, horizon: float) -> None:
        heap = self._heap
        while heap and heap[0][0] <= horizon:
            ev = heapq.heappop(heap)
            fn = ev[2]
            if fn is None:
                self._dead -= 1
                continue
            # Mark executed *before* the callback: a nested cancel of this
            # same (already-popped) event must be a no-op, or _dead drifts.
            ev[2] = None
            self.now = ev[0]
            fn()
            self.processed += 1
        self.now = max(self.now, horizon)

    def empty(self) -> bool:
        return len(self._heap) == self._dead


class SimExecutor(Executor):
    """Discrete-event backend: jobs are generators of bounded phases.

    Owns the virtual clock, the per-slot run-end event handles (cancelled
    on stop instead of token-bumped, so stale closures never linger in the
    heap), and the phase machinery (:meth:`advance`) that turns a job's
    behaviour generator into wake/block/lock transitions against the
    shared core.
    """

    single_threaded = True

    def __init__(self) -> None:
        self.clock = SimClock()
        self._run_events: dict[int, list] = {}   # sid -> pending run-end handle

    # ---------------------------------------------------- Executor protocol
    @property
    def now(self) -> float:
        return self.clock.now

    def defer(self, dt: float, fn: Callable[[], None]) -> None:
        self.clock.after(dt, fn)

    def guard(self) -> ContextManager:
        # Single-threaded event loop: lifecycle code needs no locking.
        return _NULL_GUARD

    def deliver_kick(self, slot: Slot, preempt: bool) -> None:
        if not slot.online:
            return
        if slot.current is None:
            self.core.schedule_next(slot)
        elif preempt:
            self.core.preempt_slot(slot)

    def job_started(self, slot: Slot) -> None:
        self._arm_run_end(slot)

    def job_stopping(self, slot: Slot) -> None:
        ev = self._run_events.pop(slot.sid, None)
        if ev is not None:
            self.clock.cancel(ev)                # cancel in-flight run-end event

    def job_preempted(self, job: Job, slot: Slot, used: float) -> None:
        job.burst_remaining -= used
        if job.burst_remaining <= 1e-12:
            # Raced with burst completion; let the phase machine finish it.
            job.burst_remaining = 0.0
            self.advance(job)
        else:
            self.core.requeue(job)

    def interrupt(self, slot: Slot) -> None:
        self.core.preempt_slot(slot)

    def slot_added(self, slot: Slot) -> None:
        self.clock.after(0.0, lambda: self.core.schedule_next(slot))

    # ------------------------------------------------------- run-end events
    def _arm_run_end(self, slot: Slot) -> None:
        job = slot.current
        run_for = min(job.burst_remaining, slot.slice_budget)
        stale = self._run_events.get(slot.sid)
        if stale is not None:                    # defensive: never two armed
            self.clock.cancel(stale)
        self._run_events[slot.sid] = self.clock.after(
            run_for, lambda: self._run_end(slot))

    def _run_end(self, slot: Slot) -> None:
        # Cancellation handles staleness: if this fires, the run it was
        # armed for is still current (stop_job cancels on every stop path).
        self._run_events.pop(slot.sid, None)
        if slot.current is None:
            return
        core = self.core
        job = slot.current
        used = core.now - slot.run_started
        job.burst_remaining -= used
        if job.burst_remaining <= 1e-12:
            job.burst_remaining = 0.0
            core.stop_job(slot, used, reason="complete")
            self.advance(job, from_slot=slot)
            core.schedule_next(slot)
        else:
            # Slice expiry: charge, requeue, pick next (paper: re-enqueue path).
            core.stop_job(slot, used, reason="slice")
            core.requeue(job)
            core.schedule_next(slot)

    # ------------------------------------------------------- phase machinery
    def add_job(self, job: Job, at: float = 0.0) -> None:
        self.core.jobs[job.jid] = job
        self.clock.at(at, lambda: self.advance(job))

    def advance(self, job: Job, from_slot: Optional[Slot] = None) -> None:
        """Drive the job's behaviour generator until it needs CPU or sleeps.

        Phases are advanced with ``generator.send(resume_value)`` so that
        zero-time probes (``TryLock``) can return results into the workload
        generator (spin-acquire loops, see ``core.locks.spin_acquire``).
        """
        core = self.core
        if job.state == JobState.EXITED:
            return
        while True:
            try:
                # send(None) on a fresh generator is next(); resume_value is
                # only ever non-None after the generator has started.
                value, job.resume_value = job.resume_value, None
                ph = job.behavior.send(value)
            except StopIteration:
                self._exit(job)
                return
            except Exception as e:               # noqa: BLE001
                # Behaviour crashed mid-phase: the sim analogue of a live
                # chunk raising -- contain it (locks, hints, retry policy).
                core.panic_job(job, exc=e, trace_back=traceback.format_exc())
                return
            if isinstance(ph, Burst):
                job.burst_remaining = ph.duration
                job.current_request = ph.request_id
                if from_slot is not None and from_slot.online:
                    # Back-to-back burst (no voluntary sleep): requeue, not
                    # wakeup -- no sleeper credit, and the policy decides
                    # whether the job keeps the slot (FIFO front / vruntime
                    # order / fair-server window all apply here).
                    job.state = JobState.RUNNABLE
                    job.wakeup_time = -1.0
                    core.requeue(job)
                    core.schedule_next(from_slot)
                else:
                    core.wake(job)
                return
            elif isinstance(ph, Block):
                job.state = JobState.BLOCKED
                self.clock.after(ph.duration, lambda j=job: self.advance(j))
                return
            elif isinstance(ph, TryLock):
                job.resume_value = ph.lock.try_acquire(job)
            elif isinstance(ph, RequestBegin):
                job.request_started_at = core.now
            elif isinstance(ph, RequestEnd):
                job.completed_requests += 1
                core.metrics.record_request(
                    job.group.name, core.now - job.request_started_at, core.now)
            elif isinstance(ph, AcquireLock):
                lock: SimLock = ph.lock
                if lock.try_acquire(job):
                    job.resume_value = True
                    continue
                lock.park(job)
                job.state = JobState.LOCK_WAIT       # parked; release hands off
                return
            elif isinstance(ph, ReleaseLock):
                woken = ph.lock.release(job)
                if woken is not None:
                    woken.resume_value = True
                    self.advance(woken)              # hand-off: waiter proceeds
            elif isinstance(ph, PanicExit):
                # Stuck-spinlock watchdog: same containment path as a
                # crashed behaviour (PostgreSQL PANICs the process; a job
                # with a RetryPolicy models the restarted backend).
                core.panic_job(job, reason="stuck_spinlock")
                return
            elif isinstance(ph, Exit):
                self._exit(job)
                return
            else:
                raise TypeError(f"unknown phase {ph!r}")

    def _exit(self, job: Job) -> None:
        job.state = JobState.EXITED
        self.release_held_locks(job)

    def release_held_locks(self, job: Job) -> None:
        """Sleep-discipline releases hand the lock to a parked waiter; a
        job exiting (or panicking) with waiters parked must resume them or
        they sleep forever holding a granted lock."""
        for lock in list(job.held_locks):
            woken = lock.release(job)
            if woken is not None:
                woken.resume_value = True
                self.advance(woken)

    def restart_job(self, job: Job) -> bool:
        factory = job.behavior_factory
        if factory is None:
            return False                 # dead generator, no way to rebuild
        job.behavior = factory()
        job.resume_value = None
        job.burst_remaining = 0.0
        job.current_request = None
        return True

    def resume_retry(self, job: Job) -> None:
        self.advance(job)                # fresh generator wakes at its burst


class SchedKernel(SchedCore):
    """Sim-mode scheduling kernel: a thin facade over :class:`SchedCore`
    with a :class:`SimExecutor` backend.

    Shares one keyword signature with :class:`~repro.core.live.LiveKernel`
    (``policy, n_slots, kick_latency, tracer, metrics, ...``), so
    :func:`repro.core.build.build_kernel` is a thin mode switch.  The old
    positional form beyond ``(n_slots, policy)`` still works but warns.
    """

    _LEGACY_POSITIONAL = ("hints", "metrics", "kick_latency",
                          "hints_enabled", "seed")

    def __init__(
        self,
        n_slots: int,
        policy: Policy,
        *legacy,
        hints: Optional[HintTable] = None,
        metrics: Optional[Metrics] = None,
        kick_latency: float = 0.0,
        hints_enabled: bool = True,
        seed: int = 0,
        tracer: Optional[SchedTracer] = None,
    ):
        if legacy:
            if len(legacy) > len(self._LEGACY_POSITIONAL):
                raise TypeError(
                    f"SchedKernel takes at most "
                    f"{2 + len(self._LEGACY_POSITIONAL)} positional arguments")
            warnings.warn(
                "positional SchedKernel arguments beyond (n_slots, policy) "
                "are deprecated; pass hints/metrics/kick_latency/"
                "hints_enabled/seed by keyword (or use build_kernel)",
                DeprecationWarning, stacklevel=2)
            over = dict(zip(self._LEGACY_POSITIONAL, legacy))
            hints = over.get("hints", hints)
            metrics = over.get("metrics", metrics)
            kick_latency = over.get("kick_latency", kick_latency)
            hints_enabled = over.get("hints_enabled", hints_enabled)
            seed = over.get("seed", seed)
        super().__init__(n_slots, policy, SimExecutor(), hints=hints,
                         metrics=metrics, kick_latency=kick_latency,
                         hints_enabled=hints_enabled, tracer=tracer)
        self._rng_state = seed or 1

    @property
    def clock(self) -> SimClock:
        return self.executor.clock

    def create_lock(self, name: str = "") -> SimLock:
        return SimLock(self, name)

    # ------------------------------------------------------------ job control
    def add_job(self, job: Job, at: float = 0.0) -> None:
        self.executor.add_job(job, at)

    def run(self, horizon: float, warmup: float = 0.0) -> Metrics:
        self.metrics.window_start = warmup
        self.metrics.window_end = horizon
        self.clock.run_until(horizon)
        self._settle_accounting()
        return self.metrics

    def _settle_accounting(self) -> None:
        """Charge partially-elapsed runs at the horizon so utilization sums."""
        for slot in self.slots:
            job = slot.current
            if job is not None:
                used = self.now - slot.run_started
                if used > 0:
                    self.metrics.record_run(slot.sid, job.kind, job.group.name, used, self.now)
                    slot.run_started = self.now
