"""Live-mode execution backend: the same SchedCore and Policy objects as
the simulator, driving real (JAX) work on worker threads.

The shared scheduling machinery lives in :mod:`repro.core.base`
(:class:`~repro.core.base.SchedCore`); this module contributes the
**thread** backend (DESIGN.md section 2):

* :class:`ThreadExecutor` -- one host worker thread per slot; jobs provide
  ``run_chunk(budget_s) -> "done" | "blocked" | "yield"`` executing one
  bounded chunk of real work (a training microbatch, a batched decode step,
  a prefill chunk).  Preemption is chunk-granular: a kick records a
  per-slot preempt request which long chunks may poll via
  :meth:`LiveKernel.preempt_requested`, and the scheduler simply does not
  re-dispatch background work while time-sensitive work is queued.
* :class:`LiveKernel` -- the live facade over :class:`SchedCore`
  (``start`` / ``stop`` / ``create_lock``).

Locks: :class:`LiveLock` is the engine-lock analogue of ``SimLock`` -- a
real ``threading.Lock`` instrumented with HintTable reporting, so the
priority-inversion machinery (boosting) works identically in live mode.
"""
from __future__ import annotations

import itertools
import threading
import time
import traceback
import warnings
from contextlib import contextmanager
from typing import Callable, ContextManager, Optional

from .base import Executor, Policy, SchedCore, Slot
from .hints import HintTable
from .metrics import Metrics
from .task import Job, JobState
from .trace import SchedTracer

_live_ids = itertools.count(1)


class LiveJob(Job):
    def __init__(self, group, run_chunk: Callable[[float], str],
                 name: str = "", kind: str = "live", retry_policy=None):
        super().__init__(group, behavior=None, name=name or f"live{next(_live_ids)}",
                         kind=kind, retry_policy=retry_policy)
        self._run_chunk = run_chunk


class ThreadExecutor(Executor):
    """Worker-thread backend: real wall-clock time, chunk-granular dispatch.

    The mutation guard is a condition variable over a re-entrant lock, so
    hint callbacks and nested lifecycle calls (enqueue -> kick -> ...) are
    safe from any thread -- including worker threads already inside the
    guard.  Exiting the guard always notifies idle workers.
    """

    def __init__(self) -> None:
        self._t0 = time.monotonic()
        self._cond = threading.Condition()       # default lock is an RLock
        self._stop = False
        self._started = False
        self._threads: list = []
        self._timers: list = []
        self._preempt: set[int] = set()          # sids with a pending preempt

    # ---------------------------------------------------- Executor protocol
    @property
    def now(self) -> float:
        return time.monotonic() - self._t0

    def defer(self, dt: float, fn: Callable[[], None]) -> None:
        if dt <= 0:
            fn()
            return
        t = threading.Timer(dt, self._fire_deferred, args=(fn,))
        t.daemon = True
        with self._cond:
            if self._stop:
                return
            self._timers = [x for x in self._timers if x.is_alive()]
            self._timers.append(t)
        t.start()

    def _fire_deferred(self, fn: Callable[[], None]) -> None:
        with self._cond:
            if self._stop:
                return
        fn()

    @contextmanager
    def _guard(self):
        with self._cond:
            try:
                yield
            finally:
                self._cond.notify_all()

    def guard(self) -> ContextManager:
        return self._guard()

    def deliver_kick(self, slot: Slot, preempt: bool) -> None:
        with self._cond:
            if preempt and slot.current is not None:
                self.core.metrics.preemptions += 1
                self.core.trace("preempt_slot", slot=slot.sid,
                                job=slot.current)
                self._preempt.add(slot.sid)
            self._cond.notify_all()

    def interrupt(self, slot: Slot) -> None:
        # Chunk-granular: the worker stops the job at the chunk boundary and
        # the policy (which only sees online slots) migrates it elsewhere.
        with self._cond:
            if slot.current is not None:
                self._preempt.add(slot.sid)
            self._cond.notify_all()

    def slot_added(self, slot: Slot) -> None:
        with self._cond:
            if self._started and not self._stop:
                self._spawn_worker(slot)
            self._cond.notify_all()

    def preempt_requested(self, slot: Slot) -> bool:
        """Chunk-granular preempt poll for long-running chunks."""
        return slot.sid in self._preempt

    # -------------------------------------------------------------- workers
    def start(self) -> None:
        with self._cond:
            self._started = True
            for slot in self.core.slots:
                self._spawn_worker(slot)

    def _spawn_worker(self, slot: Slot) -> None:
        t = threading.Thread(target=self._worker, args=(slot,), daemon=True)
        self._threads.append(t)
        t.start()

    def stop(self) -> None:
        with self._cond:
            self._stop = True
            for t in self._timers:
                t.cancel()
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def _worker(self, slot: Slot) -> None:
        core = self.core
        while True:
            with self._cond:
                while True:
                    if self._stop:
                        return
                    if slot.online:
                        core.schedule_next(slot)     # shared dispatch + start
                        if slot.current is not None:
                            break
                    self._cond.wait(timeout=0.05)
                job = slot.current
                self._preempt.discard(slot.sid)
                budget = slot.slice_budget
                runner = getattr(job, "_run_chunk", None) or job.run_chunk
            t0 = time.monotonic()
            err: Optional[BaseException] = None
            tb = ""
            try:
                status = runner(budget)              # real work, no lock held
            except Exception as e:                   # noqa: BLE001
                # A crashed chunk is a *panic*, not a completion: traced,
                # counted, locks force-released, retry policy applied.
                status = "panic"
                err, tb = e, traceback.format_exc()
            used = time.monotonic() - t0
            with self._cond:
                core.stop_job(slot, used, reason=status)  # shared stop bookkeeping
                self._preempt.discard(slot.sid)
                if status == "panic":
                    core.panic_job(job, slot=slot, exc=err, trace_back=tb)
                elif status == "done":
                    job.state = JobState.EXITED
                elif status == "blocked":
                    job.state = JobState.BLOCKED
                else:
                    core.requeue(job)
                self._cond.notify_all()


class LiveKernel(SchedCore):
    """Thread-based kernel: a thin facade over :class:`SchedCore` with a
    :class:`ThreadExecutor` backend.

    Shares one keyword signature with :class:`~repro.core.kernel.SchedKernel`
    (``policy, n_slots, kick_latency, tracer, metrics, ...``) so
    :func:`repro.core.build.build_kernel` is a thin mode switch; ``seed`` is
    accepted for signature parity and unused (real threads, real clock).
    The old positional form beyond ``(n_slots, policy)`` still works but
    warns.
    """

    _LEGACY_POSITIONAL = ("hints", "hints_enabled", "kick_latency")

    def __init__(self, n_slots: int, policy: Policy, *legacy,
                 hints: Optional[HintTable] = None,
                 metrics: Optional[Metrics] = None,
                 kick_latency: float = 0.0,
                 hints_enabled: bool = True,
                 seed: int = 0,
                 tracer: Optional[SchedTracer] = None):
        if legacy:
            if len(legacy) > len(self._LEGACY_POSITIONAL):
                raise TypeError(
                    f"LiveKernel takes at most "
                    f"{2 + len(self._LEGACY_POSITIONAL)} positional arguments")
            warnings.warn(
                "positional LiveKernel arguments beyond (n_slots, policy) "
                "are deprecated; pass hints/hints_enabled/kick_latency by "
                "keyword (or use build_kernel)",
                DeprecationWarning, stacklevel=2)
            over = dict(zip(self._LEGACY_POSITIONAL, legacy))
            hints = over.get("hints", hints)
            hints_enabled = over.get("hints_enabled", hints_enabled)
            kick_latency = over.get("kick_latency", kick_latency)
        del seed                                   # parity-only, no sim RNG
        super().__init__(n_slots, policy, ThreadExecutor(), hints=hints,
                         metrics=metrics, kick_latency=kick_latency,
                         hints_enabled=hints_enabled, tracer=tracer)

    def start(self) -> None:
        self.executor.start()

    def stop(self) -> None:
        self.executor.stop()

    def create_lock(self, name: str = "") -> "LiveLock":
        return LiveLock(self, name)

    def preempt_requested(self, slot: Slot) -> bool:
        return self.executor.preempt_requested(slot)


class LiveLock:
    """Engine lock with hint instrumentation (LWLock analogue, live mode)."""

    _ids = itertools.count(10_000)

    def __init__(self, kernel: SchedCore, name: str = ""):
        self.lock_id = next(self._ids)
        self.name = name or f"livelock{self.lock_id}"
        self.kernel = kernel
        self._lock = threading.Lock()
        self.holder: Optional[Job] = None

    def acquire(self, job: Job, timeout: float = 30.0) -> bool:
        if not self._lock.acquire(blocking=False):
            holder = self.holder
            self.kernel.trace(
                "lock_wait", job=job, lock=self.name, lock_id=self.lock_id,
                holder=holder.name if holder else "",
                holder_jid=holder.jid if holder else -1)
            if self.kernel.hints_enabled:
                self.kernel.hints.report_wait_start(job, self.lock_id)
            ok = self._lock.acquire(timeout=timeout)
            if not ok:
                # Timed out: retract the wait entry, or the hint table
                # keeps boosting the holder on behalf of a waiter that
                # gave up long ago (unbounded priority inversion).
                self.kernel.trace("lock_timeout", job=job, lock=self.name,
                                  lock_id=self.lock_id)
                if self.kernel.hints_enabled:
                    self.kernel.hints.report_wait_end(job, self.lock_id)
                return False
        self.holder = job
        job.held_locks.add(self)
        self.kernel.trace("lock_acquire", job=job, lock=self.name,
                          lock_id=self.lock_id)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_wait_end(job, self.lock_id)
            self.kernel.hints.report_lock_acquired(job, self.lock_id)
        return True

    def release(self, job: Job) -> None:
        if self.holder is not job:
            # Already force-released by the panic path (or never held):
            # releasing the raw threading.Lock again would raise in
            # whatever thread got here second.
            job.held_locks.discard(self)
            return
        self.holder = None
        job.held_locks.discard(self)
        self.kernel.trace("lock_release", job=job, lock=self.name,
                          lock_id=self.lock_id)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_lock_released(job, self.lock_id)
        self._lock.release()
