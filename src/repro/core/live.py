"""Live-mode execution backend: the same SchedCore and Policy objects as
the simulator, driving real (JAX) work on worker threads.

The shared scheduling machinery lives in :mod:`repro.core.base`
(:class:`~repro.core.base.SchedCore`); this module contributes the
**thread** backend (DESIGN.md section 2):

* :class:`ThreadExecutor` -- one host worker thread per slot; jobs provide
  ``run_chunk(budget_s) -> "done" | "blocked" | "yield"`` executing one
  bounded chunk of real work (a training microbatch, a batched decode step,
  a prefill chunk).  Preemption is chunk-granular: a kick records a
  per-slot preempt request which long chunks may poll via
  :meth:`LiveKernel.preempt_requested`, and the scheduler simply does not
  re-dispatch background work while time-sensitive work is queued.
* :class:`LiveKernel` -- the live facade over :class:`SchedCore`
  (``start`` / ``stop`` / ``create_lock``).

Locks: :class:`LiveLock` is the engine-lock analogue of ``SimLock`` -- a
real ``threading.Lock`` instrumented with HintTable reporting, so the
priority-inversion machinery (boosting) works identically in live mode.
"""
from __future__ import annotations

import itertools
import threading
import time
import traceback
import warnings
from contextlib import contextmanager
from typing import Callable, ContextManager, Optional

from .base import Executor, Policy, SchedCore, Slot
from .hints import HintTable
from .metrics import Metrics
from .task import Job, JobState
from .trace import SchedTracer

_live_ids = itertools.count(1)


class LiveJob(Job):
    def __init__(self, group, run_chunk: Callable[[float], str],
                 name: str = "", kind: str = "live", retry_policy=None):
        super().__init__(group, behavior=None, name=name or f"live{next(_live_ids)}",
                         kind=kind, retry_policy=retry_policy)
        self._run_chunk = run_chunk


class ThreadExecutor(Executor):
    """Worker-thread backend: real wall-clock time, chunk-granular dispatch.

    The mutation guard is a re-entrant lock, so hint callbacks and nested
    lifecycle calls (enqueue -> kick -> ...) are safe from any thread --
    including worker threads already inside the guard.

    Two dispatch modes (DESIGN.md section 13):

    * ``"event"`` (default) -- per-slot :class:`threading.Event` parking with
      targeted wakeups: ``deliver_kick`` wakes only the kicked slot, and idle
      workers park indefinitely.  Enqueues that bypass the kick path (e.g. a
      direct enqueue onto a busy slot's DSQ) are covered by a bounded
      wake-scan on outermost guard exit, armed only when work was actually
      enqueued (``work_enqueued``), so an idle fleet never spins.
    * ``"polling"`` -- the legacy global condition variable with a
      ``wait(timeout=poll_interval)`` tick and ``notify_all`` on every guard
      exit (thundering herd).  Kept as the serving benchmark's pre-change
      baseline and as a conservative fallback.
    """

    def __init__(self, dispatch: str = "event",
                 poll_interval: float = 0.05) -> None:
        if dispatch not in ("event", "polling"):
            raise ValueError(f"dispatch must be 'event' or 'polling', "
                             f"got {dispatch!r}")
        self._t0 = time.monotonic()
        self._mu = threading.RLock()
        self._cond = threading.Condition(self._mu)   # polling-mode parking
        self._dispatch_mode = dispatch
        self._poll = poll_interval
        self._depth = 0                          # guard nesting (under _mu)
        self._stop = False
        self._started = False
        self._threads: list = []
        self._timers: list = []
        self._preempt: set[int] = set()          # sids with a pending preempt
        self._events: dict[int, threading.Event] = {}   # sid -> park event
        self._parked: set[int] = set()           # sids currently parked
        self._enq_count = 0                      # enqueues not yet serviced
                                                 # by a kick or wake-scan
        self._tl = threading.local()             # worker epilogue marker
        # Job-state settle watchers (engine shutdown path): its own small
        # lock so watchers never contend with the scheduling hot path.
        self._settle = threading.Condition(threading.Lock())
        self._settle_waiters = 0

    # ---------------------------------------------------- Executor protocol
    @property
    def now(self) -> float:
        return time.monotonic() - self._t0

    def defer(self, dt: float, fn: Callable[[], None]) -> None:
        if dt <= 0:
            fn()
            return
        handle: list = []
        t = threading.Timer(dt, self._fire_deferred, args=(fn, handle))
        handle.append(t)
        t.daemon = True
        with self._mu:
            if self._stop:
                return
            self._timers.append(t)
        t.start()

    def _fire_deferred(self, fn: Callable[[], None], handle: list) -> None:
        with self._mu:
            # Self-prune: a fired timer must not linger in _timers (they
            # used to accumulate until the next defer() swept them).
            if handle:
                try:
                    self._timers.remove(handle[0])
                except ValueError:
                    pass
            if self._stop:
                return
        fn()

    @contextmanager
    def _guard(self):
        with self._mu:
            self._depth += 1
            try:
                yield
            finally:
                self._depth -= 1
                if self._dispatch_mode == "polling":
                    self._cond.notify_all()
                elif self._depth == 0 and self._enq_count:
                    n, self._enq_count = self._enq_count, 0
                    self._wake_idle_workers(n)

    def guard(self) -> ContextManager:
        return self._guard()

    def work_enqueued(self, job) -> None:
        # Arms the guard-exit wake-scan: only actual enqueues wake parked
        # workers, so a worker re-parking (also a guard exit) cannot wake
        # itself in a spin loop.  Each unit is cancelled by the kick that
        # services it (deliver_kick), so the scan only covers kickless
        # enqueues -- the safety net, not the common path.
        self._enq_count += 1

    def _wake_idle_workers(self, n_armed: int) -> None:
        """Targeted wakeups on outermost guard exit after an enqueue: wake
        at most as many parked idle workers as there are unserviced
        enqueues (and never more than the policy has queued) -- no
        thundering herd, and none at all when the queues are empty.  Caller
        holds the mutation lock."""
        if self._stop or not self._parked:
            return
        n = min(n_armed, self.core.policy.queued_count())
        for sid in list(self._parked):
            if n <= 0:
                break
            slot = self.core.slots[sid]
            if slot.online and slot.current is None:
                evt = self._events.get(sid)
                if evt is not None and not evt.is_set():
                    evt.set()
                    n -= 1

    def deliver_kick(self, slot: Slot, preempt: bool) -> None:
        with self._mu:
            if preempt and slot.current is not None:
                self.core.metrics.preemptions += 1
                self.core.trace("preempt_slot", slot=slot.sid,
                                job=slot.current)
                self._preempt.add(slot.sid)
            if self._dispatch_mode == "polling":
                self._cond.notify_all()
            else:
                # This kick services one pending enqueue: the kicked slot
                # either unparks here or rescans at its next chunk
                # boundary, so the guard-exit wake-scan need not also fire.
                if self._enq_count:
                    self._enq_count -= 1
                if (not preempt and slot.current is None
                        and getattr(self._tl, "rescan_sid", None) == slot.sid):
                    # Redundant self-kick: a worker epilogue requeued work
                    # and the policy kicked the worker's own (momentarily
                    # idle) slot -- that worker rescans immediately after,
                    # so setting its event would only cause a futile
                    # park/unpark cycle on its next idle pass.
                    return
                evt = self._events.get(slot.sid)
                if evt is not None:
                    evt.set()                    # wake only the kicked slot

    def interrupt(self, slot: Slot) -> None:
        # Chunk-granular: the worker stops the job at the chunk boundary and
        # the policy (which only sees online slots) migrates it elsewhere.
        with self._mu:
            if slot.current is not None:
                self._preempt.add(slot.sid)
            if self._dispatch_mode == "polling":
                self._cond.notify_all()
            else:
                evt = self._events.get(slot.sid)
                if evt is not None:
                    evt.set()

    def slot_added(self, slot: Slot) -> None:
        with self._mu:
            self._events.setdefault(slot.sid, threading.Event())
            self._reap_threads_locked()
            if self._started and not self._stop:
                self._spawn_worker(slot)
            if self._dispatch_mode == "polling":
                self._cond.notify_all()

    def preempt_requested(self, slot: Slot) -> bool:
        """Chunk-granular preempt poll for long-running chunks."""
        return slot.sid in self._preempt

    # -------------------------------------------------------------- workers
    def start(self) -> None:
        with self._mu:
            self._started = True
            for slot in self.core.slots:
                self._events.setdefault(slot.sid, threading.Event())
                self._spawn_worker(slot)

    def _spawn_worker(self, slot: Slot) -> None:
        t = threading.Thread(target=self._worker, args=(slot,), daemon=True)
        self._threads.append(t)
        t.start()

    def _reap_threads_locked(self) -> None:
        # Exited workers (stopped executors, drained re-spawns) used to
        # accumulate here forever and get joined again on every stop.
        self._threads = [t for t in self._threads if t.is_alive()]

    def stop(self) -> None:
        with self._mu:
            self._stop = True
            for t in self._timers:
                t.cancel()
            self._timers.clear()
            self._cond.notify_all()
            for evt in self._events.values():
                evt.set()                        # unpark everyone to exit
            threads = list(self._threads)
        with self._settle:
            self._settle.notify_all()
        for t in threads:
            t.join(timeout=5.0)
        with self._mu:
            self._reap_threads_locked()

    def wait_job_settle(self, job, states=("blocked", "exited", "new"),
                        timeout: float = 2.0) -> str:
        """Block until ``job.state`` settles into one of ``states`` (or the
        executor stops / ``timeout`` lapses); returns the final state value.
        Event-driven replacement for busy-polling job state at shutdown:
        worker epilogues notify after every chunk's state transition."""
        deadline = time.monotonic() + timeout
        with self._settle:
            self._settle_waiters += 1
            try:
                while True:
                    state = job.state.value
                    if state in states or self._stop:
                        return state
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return state
                    self._settle.wait(remaining)
            finally:
                self._settle_waiters -= 1

    def _notify_settle(self) -> None:
        if self._settle_waiters:
            with self._settle:
                self._settle.notify_all()

    def _worker(self, slot: Slot) -> None:
        core = self.core
        evt = None
        with self._mu:
            evt = self._events.setdefault(slot.sid, threading.Event())
        while True:
            job = None
            park = False
            with self._guard():
                if self._stop:
                    return
                if slot.online:
                    core.schedule_next(slot)     # shared dispatch + start
                    job = slot.current
                if job is None:
                    if self._dispatch_mode == "polling":
                        self._cond.wait(timeout=self._poll)
                    else:
                        # Clear-then-park under the lock: any kick or
                        # enqueue serialized after this point re-sets the
                        # event, so the wait below can never miss a wakeup.
                        evt.clear()
                        self._parked.add(slot.sid)
                        park = True
                        if core._traced:
                            core.trace("park", slot=slot.sid)
                else:
                    self._preempt.discard(slot.sid)
                    budget = slot.slice_budget
                    runner = getattr(job, "_run_chunk", None) or job.run_chunk
            if job is None:
                if park:
                    t_park = time.monotonic()
                    evt.wait()                   # park until targeted wakeup
                    waited = time.monotonic() - t_park
                    with self._mu:
                        self._parked.discard(slot.sid)
                    if core._traced:
                        core.trace("unpark", slot=slot.sid, waited=waited)
                continue
            t0 = time.monotonic()
            err: Optional[BaseException] = None
            tb = ""
            try:
                status = runner(budget)              # real work, no lock held
            except Exception as e:                   # noqa: BLE001
                # A crashed chunk is a *panic*, not a completion: traced,
                # counted, locks force-released, retry policy applied.
                status = "panic"
                err, tb = e, traceback.format_exc()
            used = time.monotonic() - t0
            # Mark the epilogue window (thread-local): a requeue in here
            # often kicks this worker's own just-idled slot, and this
            # worker rescans immediately on loop-around, so deliver_kick
            # can skip setting our park event (see deliver_kick).
            self._tl.rescan_sid = slot.sid
            try:
                with self._guard():
                    core.stop_job(slot, used, reason=status)  # shared stop bookkeeping
                    self._preempt.discard(slot.sid)
                    if status == "panic":
                        core.panic_job(job, slot=slot, exc=err, trace_back=tb)
                    elif status == "done":
                        job.state = JobState.EXITED
                    elif status == "blocked":
                        job.state = JobState.BLOCKED
                    else:
                        core.requeue(job)
            finally:
                self._tl.rescan_sid = None
            self._notify_settle()


class LiveKernel(SchedCore):
    """Thread-based kernel: a thin facade over :class:`SchedCore` with a
    :class:`ThreadExecutor` backend.

    Shares one keyword signature with :class:`~repro.core.kernel.SchedKernel`
    (``policy, n_slots, kick_latency, tracer, metrics, ...``) so
    :func:`repro.core.build.build_kernel` is a thin mode switch; ``seed`` is
    accepted for signature parity and unused (real threads, real clock).
    The old positional form beyond ``(n_slots, policy)`` still works but
    warns.
    """

    _LEGACY_POSITIONAL = ("hints", "hints_enabled", "kick_latency")

    def __init__(self, n_slots: int, policy: Policy, *legacy,
                 hints: Optional[HintTable] = None,
                 metrics: Optional[Metrics] = None,
                 kick_latency: float = 0.0,
                 hints_enabled: bool = True,
                 seed: int = 0,
                 tracer: Optional[SchedTracer] = None,
                 dispatch: str = "event",
                 poll_interval: float = 0.05):
        if legacy:
            if len(legacy) > len(self._LEGACY_POSITIONAL):
                raise TypeError(
                    f"LiveKernel takes at most "
                    f"{2 + len(self._LEGACY_POSITIONAL)} positional arguments")
            warnings.warn(
                "positional LiveKernel arguments beyond (n_slots, policy) "
                "are deprecated; pass hints/hints_enabled/kick_latency by "
                "keyword (or use build_kernel)",
                DeprecationWarning, stacklevel=2)
            over = dict(zip(self._LEGACY_POSITIONAL, legacy))
            hints = over.get("hints", hints)
            hints_enabled = over.get("hints_enabled", hints_enabled)
            kick_latency = over.get("kick_latency", kick_latency)
        del seed                                   # parity-only, no sim RNG
        executor = ThreadExecutor(dispatch=dispatch,
                                  poll_interval=poll_interval)
        super().__init__(n_slots, policy, executor, hints=hints,
                         metrics=metrics, kick_latency=kick_latency,
                         hints_enabled=hints_enabled, tracer=tracer)

    def start(self) -> None:
        self.executor.start()

    def stop(self) -> None:
        self.executor.stop()

    def create_lock(self, name: str = "") -> "LiveLock":
        return LiveLock(self, name)

    def preempt_requested(self, slot: Slot) -> bool:
        return self.executor.preempt_requested(slot)


class LiveLock:
    """Engine lock with hint instrumentation (LWLock analogue, live mode)."""

    _ids = itertools.count(10_000)

    def __init__(self, kernel: SchedCore, name: str = ""):
        self.lock_id = next(self._ids)
        self.name = name or f"livelock{self.lock_id}"
        self.kernel = kernel
        self._lock = threading.Lock()
        self.holder: Optional[Job] = None

    def acquire(self, job: Job, timeout: float = 30.0) -> bool:
        if not self._lock.acquire(blocking=False):
            holder = self.holder
            self.kernel.trace(
                "lock_wait", job=job, lock=self.name, lock_id=self.lock_id,
                holder=holder.name if holder else "",
                holder_jid=holder.jid if holder else -1)
            if self.kernel.hints_enabled:
                self.kernel.hints.report_wait_start(job, self.lock_id)
            ok = self._lock.acquire(timeout=timeout)
            if not ok:
                # Timed out: retract the wait entry, or the hint table
                # keeps boosting the holder on behalf of a waiter that
                # gave up long ago (unbounded priority inversion).
                self.kernel.trace("lock_timeout", job=job, lock=self.name,
                                  lock_id=self.lock_id)
                if self.kernel.hints_enabled:
                    self.kernel.hints.report_wait_end(job, self.lock_id)
                return False
        self.holder = job
        job.held_locks.add(self)
        self.kernel.trace("lock_acquire", job=job, lock=self.name,
                          lock_id=self.lock_id)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_wait_end(job, self.lock_id)
            self.kernel.hints.report_lock_acquired(job, self.lock_id)
        return True

    def release(self, job: Job) -> None:
        if self.holder is not job:
            # Already force-released by the panic path (or never held):
            # releasing the raw threading.Lock again would raise in
            # whatever thread got here second.
            job.held_locks.discard(self)
            return
        self.holder = None
        job.held_locks.discard(self)
        self.kernel.trace("lock_release", job=job, lock=self.name,
                          lock_id=self.lock_id)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_lock_released(job, self.lock_id)
        self._lock.release()
