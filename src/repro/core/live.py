"""Live-mode scheduling kernel: the same Policy objects as the simulator,
driving real (JAX) work on worker threads.

A *slot* here is a device execution context served by one host thread; jobs
provide ``run_chunk(budget_s) -> "done" | "blocked" | "yield"`` executing one
bounded chunk of real work (a training microbatch, a batched decode step, a
prefill chunk). Preemption is chunk-granular (DESIGN.md section 2): a kick
sets ``slot.preempt`` which long chunks may poll, and the scheduler simply
does not re-dispatch background work while time-sensitive work is queued.

Locks: :class:`LiveLock` is the engine-lock analogue of ``SimLock`` -- a
real ``threading.Lock`` instrumented with HintTable reporting, so the
priority-inversion machinery (boosting) works identically in live mode.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Callable, Optional

from .hints import HintTable
from .kernel import Policy, Slot
from .metrics import Metrics
from .task import Job, JobState, Tier, WorkloadGroup
from .dsq import GroupDSQ

_live_ids = itertools.count(1)


class LiveJob(Job):
    def __init__(self, group: WorkloadGroup, run_chunk: Callable[[float], str],
                 name: str = "", kind: str = "live"):
        super().__init__(group, behavior=None, name=name or f"live{next(_live_ids)}",
                         kind=kind)
        self._run_chunk = run_chunk


class LiveKernel:
    """Thread-based kernel exposing the attribute surface policies use."""

    def __init__(self, n_slots: int, policy: Policy,
                 hints: Optional[HintTable] = None, hints_enabled: bool = True):
        self.slots = [Slot(i) for i in range(n_slots)]
        for s in self.slots:
            s.preempt = False
        self.policy = policy
        self.hints = hints or HintTable()
        self.hints_enabled = hints_enabled
        self.metrics = Metrics()
        self.groups: dict[str, WorkloadGroup] = {}
        self.kick_latency = 0.0
        self._t0 = time.monotonic()
        self._cond = threading.Condition()
        self._stop = False
        self._threads: list = []
        policy.attach(self)
        self.hints.on_boost = lambda j: self._with_lock(self.policy.on_boost, j)
        self.hints.on_unboost = lambda j: self._with_lock(self.policy.on_unboost, j)

    # ------------------------------------------------------------- plumbing
    @property
    def now(self) -> float:
        return time.monotonic() - self._t0

    @property
    def clock(self):  # pragma: no cover - compat shim
        return self

    def online_slots(self) -> list:
        return [s for s in self.slots if s.online]

    def create_group(self, name: str, tier: Tier, weight: float = 100.0,
                     **kw) -> WorkloadGroup:
        g = WorkloadGroup(name, tier, weight, **kw)
        g.dsq = GroupDSQ()
        self.groups[name] = g
        return g

    def _with_lock(self, fn, *a):
        # hint callbacks may fire from a thread already holding the lock
        if self._cond._lock.locked() and threading.current_thread() in self._threads:
            fn(*a)
        else:
            with self._cond:
                fn(*a)
                self._cond.notify_all()

    # ------------------------------------------------------------- schedule
    def wake(self, job: Job) -> None:
        with self._cond:
            job.state = JobState.RUNNABLE
            job.wakeup_time = self.now
            job.location = None
            self.policy.enqueue(job, requeue=False)
            self._cond.notify_all()

    def requeue(self, job: Job) -> None:
        job.state = JobState.RUNNABLE
        job.location = None
        self.policy.enqueue(job, requeue=True)

    def kick(self, slot: Slot, preempt: bool = False) -> None:
        self.metrics.kicks += 1
        if preempt and slot.current is not None:
            self.metrics.preemptions += 1
            slot.preempt = True
        self._cond.notify_all()

    # -------------------------------------------------------------- workers
    def start(self) -> None:
        for slot in self.slots:
            t = threading.Thread(target=self._worker, args=(slot,), daemon=True)
            self._threads.append(t)
            t.start()

    def stop(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def _worker(self, slot: Slot) -> None:
        while True:
            with self._cond:
                while True:
                    if self._stop:
                        return
                    job = self.policy.pick_next(slot)
                    if job is not None:
                        break
                    self._cond.wait(timeout=0.05)
                job.state = JobState.RUNNING
                job.location = None
                if job.wakeup_time >= 0:
                    self.metrics.record_wakeup(job.group.name,
                                               self.now - job.wakeup_time, self.now)
                    job.wakeup_time = -1.0
                job.prev_slot = slot.sid
                slot.current = job
                slot.preempt = False
                budget = self.policy.task_slice(job)
            t0 = time.monotonic()
            try:
                status = job._run_chunk(budget)       # real work, no lock held
            except Exception:                         # noqa: BLE001
                status = "done"
            used = time.monotonic() - t0
            with self._cond:
                slot.current = None
                self.policy.stopping(job, slot, used)
                self.metrics.record_run(slot.sid, job.kind, job.group.name,
                                        used, self.now)
                if status == "done":
                    job.state = JobState.EXITED
                elif status == "blocked":
                    job.state = JobState.BLOCKED
                else:
                    self.requeue(job)
                self._cond.notify_all()


class LiveLock:
    """Engine lock with hint instrumentation (LWLock analogue, live mode)."""

    _ids = itertools.count(10_000)

    def __init__(self, kernel: LiveKernel, name: str = ""):
        self.lock_id = next(self._ids)
        self.name = name or f"livelock{self.lock_id}"
        self.kernel = kernel
        self._lock = threading.Lock()
        self.holder: Optional[Job] = None

    def acquire(self, job: Job, timeout: float = 30.0) -> bool:
        if not self._lock.acquire(blocking=False):
            if self.kernel.hints_enabled:
                self.kernel.hints.report_wait_start(job, self.lock_id)
            ok = self._lock.acquire(timeout=timeout)
            if not ok:
                return False
        self.holder = job
        job.held_locks.add(self)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_wait_end(job, self.lock_id)
            self.kernel.hints.report_lock_acquired(job, self.lock_id)
        return True

    def release(self, job: Job) -> None:
        self.holder = None
        job.held_locks.discard(self)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_lock_released(job, self.lock_id)
        self._lock.release()
