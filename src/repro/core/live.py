"""Live-mode execution backend: the same SchedCore and Policy objects as
the simulator, driving real (JAX) work on worker threads.

The shared scheduling machinery lives in :mod:`repro.core.base`
(:class:`~repro.core.base.SchedCore`); this module contributes the
**thread** backend (DESIGN.md section 2):

* :class:`ThreadExecutor` -- one host worker thread per slot; jobs provide
  ``run_chunk(budget_s) -> "done" | "blocked" | "yield"`` executing one
  bounded chunk of real work (a training microbatch, a batched decode step,
  a prefill chunk).  Preemption is chunk-granular: a kick records a
  per-slot preempt request which long chunks may poll via
  :meth:`LiveKernel.preempt_requested`, and the scheduler simply does not
  re-dispatch background work while time-sensitive work is queued.
* :class:`LiveKernel` -- the live facade over :class:`SchedCore`
  (``start`` / ``stop`` / ``create_lock``).

Locks: :class:`LiveLock` is the engine-lock analogue of ``SimLock`` -- a
real ``threading.Lock`` instrumented with HintTable reporting, so the
priority-inversion machinery (boosting) works identically in live mode.
"""
from __future__ import annotations

import itertools
import threading
import time
from contextlib import contextmanager
from typing import Callable, ContextManager, Optional

from .base import Executor, Policy, SchedCore, Slot
from .hints import HintTable
from .task import Job, JobState

_live_ids = itertools.count(1)


class LiveJob(Job):
    def __init__(self, group, run_chunk: Callable[[float], str],
                 name: str = "", kind: str = "live"):
        super().__init__(group, behavior=None, name=name or f"live{next(_live_ids)}",
                         kind=kind)
        self._run_chunk = run_chunk


class ThreadExecutor(Executor):
    """Worker-thread backend: real wall-clock time, chunk-granular dispatch.

    The mutation guard is a condition variable over a re-entrant lock, so
    hint callbacks and nested lifecycle calls (enqueue -> kick -> ...) are
    safe from any thread -- including worker threads already inside the
    guard.  Exiting the guard always notifies idle workers.
    """

    def __init__(self) -> None:
        self._t0 = time.monotonic()
        self._cond = threading.Condition()       # default lock is an RLock
        self._stop = False
        self._started = False
        self._threads: list = []
        self._timers: list = []
        self._preempt: set[int] = set()          # sids with a pending preempt

    # ---------------------------------------------------- Executor protocol
    @property
    def now(self) -> float:
        return time.monotonic() - self._t0

    def defer(self, dt: float, fn: Callable[[], None]) -> None:
        if dt <= 0:
            fn()
            return
        t = threading.Timer(dt, self._fire_deferred, args=(fn,))
        t.daemon = True
        with self._cond:
            if self._stop:
                return
            self._timers = [x for x in self._timers if x.is_alive()]
            self._timers.append(t)
        t.start()

    def _fire_deferred(self, fn: Callable[[], None]) -> None:
        with self._cond:
            if self._stop:
                return
        fn()

    @contextmanager
    def _guard(self):
        with self._cond:
            try:
                yield
            finally:
                self._cond.notify_all()

    def guard(self) -> ContextManager:
        return self._guard()

    def deliver_kick(self, slot: Slot, preempt: bool) -> None:
        with self._cond:
            if preempt and slot.current is not None:
                self.core.metrics.preemptions += 1
                self._preempt.add(slot.sid)
            self._cond.notify_all()

    def interrupt(self, slot: Slot) -> None:
        # Chunk-granular: the worker stops the job at the chunk boundary and
        # the policy (which only sees online slots) migrates it elsewhere.
        with self._cond:
            if slot.current is not None:
                self._preempt.add(slot.sid)
            self._cond.notify_all()

    def slot_added(self, slot: Slot) -> None:
        with self._cond:
            if self._started and not self._stop:
                self._spawn_worker(slot)
            self._cond.notify_all()

    def preempt_requested(self, slot: Slot) -> bool:
        """Chunk-granular preempt poll for long-running chunks."""
        return slot.sid in self._preempt

    # -------------------------------------------------------------- workers
    def start(self) -> None:
        with self._cond:
            self._started = True
            for slot in self.core.slots:
                self._spawn_worker(slot)

    def _spawn_worker(self, slot: Slot) -> None:
        t = threading.Thread(target=self._worker, args=(slot,), daemon=True)
        self._threads.append(t)
        t.start()

    def stop(self) -> None:
        with self._cond:
            self._stop = True
            for t in self._timers:
                t.cancel()
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def _worker(self, slot: Slot) -> None:
        core = self.core
        while True:
            with self._cond:
                while True:
                    if self._stop:
                        return
                    if slot.online:
                        core.schedule_next(slot)     # shared dispatch + start
                        if slot.current is not None:
                            break
                    self._cond.wait(timeout=0.05)
                job = slot.current
                self._preempt.discard(slot.sid)
                budget = slot.slice_budget
                runner = getattr(job, "_run_chunk", None) or job.run_chunk
            t0 = time.monotonic()
            try:
                status = runner(budget)              # real work, no lock held
            except Exception:                        # noqa: BLE001
                status = "done"
            used = time.monotonic() - t0
            with self._cond:
                core.stop_job(slot, used)            # shared stop bookkeeping
                self._preempt.discard(slot.sid)
                if status == "done":
                    job.state = JobState.EXITED
                elif status == "blocked":
                    job.state = JobState.BLOCKED
                else:
                    core.requeue(job)
                self._cond.notify_all()


class LiveKernel(SchedCore):
    """Thread-based kernel: a thin facade over :class:`SchedCore` with a
    :class:`ThreadExecutor` backend."""

    def __init__(self, n_slots: int, policy: Policy,
                 hints: Optional[HintTable] = None, hints_enabled: bool = True,
                 kick_latency: float = 0.0):
        super().__init__(n_slots, policy, ThreadExecutor(), hints=hints,
                         kick_latency=kick_latency, hints_enabled=hints_enabled)

    def start(self) -> None:
        self.executor.start()

    def stop(self) -> None:
        self.executor.stop()

    def create_lock(self, name: str = "") -> "LiveLock":
        return LiveLock(self, name)

    def preempt_requested(self, slot: Slot) -> bool:
        return self.executor.preempt_requested(slot)


class LiveLock:
    """Engine lock with hint instrumentation (LWLock analogue, live mode)."""

    _ids = itertools.count(10_000)

    def __init__(self, kernel: SchedCore, name: str = ""):
        self.lock_id = next(self._ids)
        self.name = name or f"livelock{self.lock_id}"
        self.kernel = kernel
        self._lock = threading.Lock()
        self.holder: Optional[Job] = None

    def acquire(self, job: Job, timeout: float = 30.0) -> bool:
        if not self._lock.acquire(blocking=False):
            if self.kernel.hints_enabled:
                self.kernel.hints.report_wait_start(job, self.lock_id)
            ok = self._lock.acquire(timeout=timeout)
            if not ok:
                return False
        self.holder = job
        job.held_locks.add(self)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_wait_end(job, self.lock_id)
            self.kernel.hints.report_lock_acquired(job, self.lock_id)
        return True

    def release(self, job: Job) -> None:
        self.holder = None
        job.held_locks.discard(self)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_lock_released(job, self.lock_id)
        self._lock.release()
