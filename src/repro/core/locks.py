"""Instrumented engine locks (PostgreSQL LWLock / spinlock analogues).

Engine resources (KV-page allocator, parameter-publish stream, checkpoint
stream) are guarded by these locks. Every transition writes application
hints into the shared :class:`~repro.core.hints.HintTable`, mirroring the
paper's instrumentation of PostgreSQL's wait-event reporting path
(pgstat_report_wait_start/end, paper section 5.2).

Two acquisition disciplines:

* **spin** (:func:`spin_acquire`, PostgreSQL spinlock): the poll consumes a
  short CPU burst, then sleeps with exponential backoff; release does *not*
  hand off -- waiters acquire at their next poll. A watchdog PANICs the job
  after ``PANIC_ATTEMPTS`` failed polls, reproducing PostgreSQL's stuck-
  spinlock PANIC (paper sections 2, 6.6). Crucially, a waiter that never
  gets CPU can never even poll -- which is exactly what Table 4 observes
  under FIFO.
* **sleep** (``AcquireLock`` phase, LWLock analogue): waiters park; release
  hands the lock to the first waiter.
"""
from __future__ import annotations

import itertools
from typing import TYPE_CHECKING, Iterator, Optional

from .task import Block, Burst, Job, PanicExit, TryLock

if TYPE_CHECKING:  # pragma: no cover
    from .base import SchedCore

_lock_ids = itertools.count(1)

# PostgreSQL s_lock-style backoff constants.
MIN_BACKOFF = 1e-3        # 1 ms
MAX_BACKOFF = 1.0         # 1 s
BACKOFF_GROWTH = 1.5
PANIC_ATTEMPTS = 1000     # stuck-spinlock watchdog
POLL_COST = 5e-6          # CPU cost of one spin/poll round


class SimLock:
    """A sim-mode engine lock, created via ``kernel.create_lock``."""

    def __init__(self, kernel: "SchedCore", name: str = ""):
        self.lock_id = next(_lock_ids)
        self.name = name or f"lock{self.lock_id}"
        self.kernel = kernel
        self.holder: Optional[Job] = None
        self.parked: list[Job] = []                # sleep-discipline waiters
        self.acquired_at: dict[int, float] = {}    # jid -> acquisition time (metrics)

    # ------------------------------------------------------------------
    def try_acquire(self, job: Job) -> bool:
        if self.holder is None:
            self._grant(job)
            return True
        self.kernel.trace("lock_wait", job=job, lock=self.name,
                          lock_id=self.lock_id, holder=self.holder.name,
                          holder_jid=self.holder.jid)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_wait_start(job, self.lock_id)
        return False

    def _grant(self, job: Job) -> None:
        self.holder = job
        job.held_locks.add(self)
        self.acquired_at[job.jid] = self.kernel.now
        self.kernel.trace("lock_acquire", job=job, lock=self.name,
                          lock_id=self.lock_id)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_wait_end(job, self.lock_id)
            self.kernel.hints.report_lock_acquired(job, self.lock_id)

    def park(self, job: Job) -> None:
        self.parked.append(job)

    def release(self, job: Job) -> Optional[Job]:
        """Release; returns a parked waiter granted ownership (sleep
        discipline), or None (spin waiters re-poll on their own)."""
        assert self.holder is job, f"{job} releasing {self.name} it does not hold"
        self.holder = None
        job.held_locks.discard(self)
        self.kernel.trace("lock_release", job=job, lock=self.name,
                          lock_id=self.lock_id)
        if self.kernel.hints_enabled:
            self.kernel.hints.report_lock_released(job, self.lock_id)
        if self.parked:
            nxt = self.parked.pop(0)
            self._grant(nxt)
            return nxt
        return None


def spin_acquire(lock: SimLock, poll_cost: float = POLL_COST,
                 panic_attempts: int = PANIC_ATTEMPTS) -> Iterator:
    """Generator fragment (``yield from spin_acquire(lock)``) implementing
    PostgreSQL spinlock acquisition under the phase protocol."""
    attempts = 0
    backoff = 0.0
    while True:
        yield Burst(poll_cost)            # the poll itself needs the CPU
        got = yield TryLock(lock)
        if got:
            return
        attempts += 1
        if attempts >= panic_attempts:
            yield PanicExit()
            return
        backoff = MIN_BACKOFF if backoff == 0.0 else min(backoff * BACKOFF_GROWTH, MAX_BACKOFF)
        yield Block(backoff)
