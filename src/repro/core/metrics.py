"""Scheduler metrics: throughput, latency percentiles, per-slot utilization.

Per-slot busy time by job kind supports the Figure-2 reconstruction (the
paper rebuilds per-CPU execution time of CPU-bursty tasks from sched_switch
traces; we account it directly at charge time).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Optional


def percentile_sorted(s: list, q: float) -> float:
    """Nearest-rank percentile of an already-sorted list (q in [0,100])."""
    if not s:
        return float("nan")
    k = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return s[k]


def percentile(values: list, q: float) -> float:
    """Nearest-rank percentile on a copy (q in [0,100]).  Callers reading
    several quantiles should sort once and use :func:`percentile_sorted`."""
    return percentile_sorted(sorted(values), q)


class Metrics:
    def __init__(self) -> None:
        self.request_latency: dict[str, list] = defaultdict(list)   # group -> latencies
        self.wakeup_latency: dict[str, list] = defaultdict(list)    # group -> wake->run delays
        self.completed: dict[str, int] = defaultdict(int)           # group -> finished requests
        self.cpu_by_group: dict[str, float] = defaultdict(float)    # group -> slot-seconds
        self.slot_busy: dict = defaultdict(float)                   # (slot, kind) -> busy seconds
        self.preemptions: int = 0
        self.kicks: int = 0
        self.dispatches: int = 0
        self.lb_migrations: int = 0
        self.panics: list[str] = []
        self.retries: int = 0               # panic-path restarts granted
        self.quarantines: int = 0           # jobs poisoned after retries ran out
        self.window_start: float = 0.0
        self.window_end: float = 0.0

    # ------------------------------------------------------------------
    def record_run(self, slot_id: int, kind: str, group: str, dur: float, t: float) -> None:
        """Charge a run ending at ``t`` of length ``dur``, clipped to the
        measurement window.  Both ends are clamped symmetrically into
        [window_start, window_end] so a run straddling either window edge
        contributes exactly its in-window portion (and never a negative
        span): the old one-sided ``min(t, window_end)`` could place ``hi``
        before ``lo`` and silently drop the run."""
        end = self.window_end if self.window_end > 0.0 else float("inf")
        lo = min(max(t - dur, self.window_start), end)
        hi = min(max(t, self.window_start), end)
        d = hi - lo
        if d <= 0.0:
            return
        self.slot_busy[(slot_id, kind)] += d
        self.cpu_by_group[group] += d

    def record_request(self, group: str, latency: float, t: float) -> None:
        if t < self.window_start or (self.window_end and t > self.window_end):
            return
        self.completed[group] += 1
        self.request_latency[group].append(latency)

    def record_wakeup(self, group: str, delay: float, t: float) -> None:
        if t < self.window_start:
            return
        self.wakeup_latency[group].append(delay)

    # ------------------------------------------------------------------
    def throughput(self, group: str, duration: Optional[float] = None) -> float:
        dur = duration or (self.window_end - self.window_start)
        return self.completed[group] / dur if dur > 0 else 0.0

    def latency_stats(self, group: str) -> dict:
        lat = self.request_latency[group]
        if not lat:
            return {"mean": float("nan"), "p50": float("nan"), "p95": float("nan"),
                    "p99": float("nan"), "p999": float("nan"), "n": 0}
        s = sorted(lat)
        return {
            # mean sums the insertion-order list so float accumulation is
            # stable against the sort (byte-identical summaries).
            "mean": sum(lat) / len(lat),
            "p50": percentile_sorted(s, 50),
            "p95": percentile_sorted(s, 95),
            "p99": percentile_sorted(s, 99),
            "p999": percentile_sorted(s, 99.9),
            "n": len(lat),
        }

    def slot_utilization(self, kind: str, n_slots: int) -> list:
        """Per-slot busy seconds for jobs of ``kind`` (Figure 2)."""
        return [self.slot_busy.get((s, kind), 0.0) for s in range(n_slots)]

    def slot_skew(self, kind: str, n_slots: int) -> float:
        """max/mean utilization ratio across slots -- 1.0 means perfectly even."""
        u = self.slot_utilization(kind, n_slots)
        mean = sum(u) / len(u) if u else 0.0
        return (max(u) / mean) if mean > 0 else float("nan")

    def wakeup_stats(self, group: str) -> dict:
        """Wakeup-latency distribution for ``group`` (wake -> first start)."""
        w = self.wakeup_latency.get(group, [])
        if not w:
            return {"mean": float("nan"), "p95": float("nan"),
                    "max": float("nan"), "n": 0}
        s = sorted(w)
        return {"mean": sum(w) / len(w), "p95": percentile_sorted(s, 95),
                "max": s[-1], "n": len(w)}

    # ------------------------------------------------------------------
    def summary(self, groups: Optional[list] = None,
                n_slots: Optional[int] = None) -> dict:
        """The one read surface for consumers: a nested dict of everything
        above.  ``experiment.MixResult``, ``benchmarks``, the launch
        drivers, and ``KernelReport`` all read this instead of assembling
        their own percentile dicts.

        ``groups`` defaults to every group seen; pass an explicit list to
        include groups with no activity.  ``n_slots`` adds the per-slot
        utilization block (Figure 2)."""
        if groups is None:
            groups = sorted(set(self.completed) | set(self.request_latency)
                            | set(self.cpu_by_group) | set(self.wakeup_latency))
        counters = {"preemptions": self.preemptions, "kicks": self.kicks,
                    "dispatches": self.dispatches,
                    "lb_migrations": self.lb_migrations,
                    "panics": list(self.panics)}
        # Fault counters appear only on faulting runs: fault-free summaries
        # stay byte-identical to the committed microbench baseline
        # (BENCH_8.json compares summary hashes exactly).
        if self.retries:
            counters["retries"] = self.retries
        if self.quarantines:
            counters["quarantines"] = self.quarantines
        out = {
            "window": {"start": self.window_start, "end": self.window_end,
                       "duration": max(0.0, self.window_end - self.window_start)},
            "counters": counters,
            "groups": {
                g: {"completed": self.completed.get(g, 0),
                    "throughput": self.throughput(g),
                    "cpu_s": self.cpu_by_group.get(g, 0.0),
                    "latency": self.latency_stats(g),
                    "wakeup": self.wakeup_stats(g)}
                for g in groups
            },
        }
        if n_slots is not None:
            kinds = sorted({k for (_, k) in self.slot_busy})
            out["slots"] = {
                "n": n_slots,
                "busy_by_kind": {k: self.slot_utilization(k, n_slots)
                                 for k in kinds},
                "skew_by_kind": {k: self.slot_skew(k, n_slots)
                                 for k in kinds},
            }
        return out
