"""Baseline scheduling policies the paper evaluates UFS against.

* :class:`VDFPolicy`  -- EEVDF analogue: per-slot runqueues, virtual-deadline
  ordering, wakeup placement with the idle-sibling scan pathology, periodic +
  gated-newidle load balancing (paper section 3).
* :class:`IdlePolicy` -- SCHED_IDLE analogue for background jobs on top of VDF.
* :class:`RTPolicy`   -- SCHED_FIFO / SCHED_RR analogue with global RT queue,
  immediate cross-slot preemption and the "fair server" (RT throttling) that
  guarantees ~5% to the normal class (paper sections 3, 6.6).
"""
from .vdf import VDFPolicy
from .idle import IdlePolicy
from .rt import RTPolicy
from ..ufs import UFSPolicy

POLICIES = {
    "ufs": lambda: UFSPolicy(),
    "vdf": lambda: VDFPolicy(),
    "eevdf": lambda: VDFPolicy(),
    "idle": lambda: IdlePolicy(),
    "fifo": lambda: RTPolicy(quantum=None),
    "rr": lambda: RTPolicy(quantum=0.1),
}


def make_policy(name: str):
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; options: {sorted(POLICIES)}")
