"""IDLE baseline: SCHED_IDLE analogue for background work on top of VDF.

Background-tier jobs run with the idle-class weight (3, as in CFS's
WEIGHT_IDLEPRIO), sort after every normal job on their runqueue, and never
trigger wakeup preemption. The paper observes that this configuration shares
EEVDF's placement pathology -- which it does here by construction, since the
placement path is inherited unchanged.
"""
from __future__ import annotations

from ..task import Job, Tier
from ..vruntime import WEIGHT_SCALE
from .vdf import VDFPolicy

IDLE_WEIGHT = 3.0
IDLE_KEY_OFFSET = 1e12   # idle-class jobs sort after all normal jobs


class IdlePolicy(VDFPolicy):
    name = "idle"

    def _is_idle_class(self, job: Job) -> bool:
        return job.group.tier == Tier.BACKGROUND and not job.boosted

    def _weight(self, job: Job) -> float:
        if self._is_idle_class(job):
            return IDLE_WEIGHT
        return super()._weight(job)

    def _deadline(self, job: Job) -> float:
        d = job.vruntime + self.base_slice * (WEIGHT_SCALE / self._weight(job))
        if self._is_idle_class(job):
            d += IDLE_KEY_OFFSET
        return d

    def _preempts(self, new: Job, cur: Job) -> bool:
        if self._is_idle_class(new):
            return False                      # idle class never preempts
        if self._is_idle_class(cur):
            return True                       # any normal task preempts idle
        return super()._preempts(new, cur)

    def _scan_idle(self, slot) -> bool:
        """sched_idle_cpu(): a slot running only idle-class work counts as
        idle for wakeup placement -- which funnels every waking bursty task
        toward the same idle-class slots and stacks them (the paper finds
        IDLE shares EEVDF's failure mode)."""
        if slot.idle:
            return True
        cur = slot.current
        if cur is None or not self._is_idle_class(cur):
            return False
        return all(self._is_idle_class(j) for j in slot.local_dsq.jobs())
