"""RT baseline: SCHED_FIFO / SCHED_RR analogue (paper sections 2, 3, 6.6).

Time-sensitive-tier jobs are real-time (priority 99); background-tier jobs
fall into an embedded fair (normal) class below them, exactly like the
paper's Table 2 configurations (FIFO/RR prio 99 + NORMAL weight 1).

Modelled mechanisms:

* **per-slot RT runqueues** (as in Linux): a waking RT task goes to its
  previous CPU if it can preempt the current task (lower class), else
  ``find_lowest_rq`` (an idle slot, then one running fair-class work),
  else it queues on its previous slot behind the same-priority runner --
  under FIFO that runner never yields, which is the 50:50 collapse;
* **pull balancing**: a slot that runs out of RT work pulls a queued
  (pushable) RT task from an overloaded slot -- keeps MIN:MAX healthy;
* FIFO: runnable RT task runs until it blocks (infinite slice); RR: 100 ms
  quanta (Linux RR_TIMESLICE default), expired tasks requeue at the tail --
  a bursty task that blocks early loses the remainder of its turn and then
  waits out its neighbour's full quantum, the failure the paper shows;
* no virtual-runtime accounting inside the RT class (the paper's point);
* **RT throttling / fair server**: the normal class is guaranteed ~5% of
  each slot-second (Linux sched_rt_runtime_us = 950000/1000000): when a
  slot's RT usage reaches 95% of the 1 s window and fair work is runnable,
  the slot serves the fair class for the rest of the window. This is what
  lets the lock-holding background task limp forward in Table 4 and what
  puts the occasional ~tens-of-ms spike in the RT tail latencies.
"""
from __future__ import annotations

import itertools

from ..base import Policy, Slot
from ..dsq import GroupDSQ
from ..task import Job, JobState, Tier
from ..vruntime import WEIGHT_SCALE

FAIR_SLICE = 0.003
RT_WINDOW = 1.0               # throttling window
RT_RUNTIME_FRAC = 0.95        # RT may use 95% of each window
FAIR_BUDGET = 0.05            # fair-server budget per window (~5%)


class RTPolicy(Policy):
    """quantum=None -> SCHED_FIFO; quantum=0.1 -> SCHED_RR."""

    def __init__(self, quantum=None):
        self.quantum = quantum
        self.name = "fifo" if quantum is None else "rr"
        # Per-instance FIFO sequence: two kernels built in one process must
        # observe identical tie-break sequences (was a module global).
        self._seq = itertools.count()
        self.fair_queue = GroupDSQ()          # global fair rq, keyed by vruntime
        self.fair_vmin = 0.0
        self.rt_since: dict[int, float] = {}  # sid -> RT usage since last fair grant
        # sid -> fair-server window end: policy-private per-slot state (was a
        # field bolted onto Slot; the core's Slot is now policy-agnostic).
        self.fair_until: dict[int, float] = {}

    # ------------------------------------------------------------------
    def queued_count(self) -> int:
        # The global fair rq is policy-private state the generic scan
        # (local + group DSQs) cannot see.
        return super().queued_count() + len(self.fair_queue)

    def _is_rt(self, job: Job) -> bool:
        return job.tier == Tier.TIME_SENSITIVE

    def _allowed(self, job: Job, slot: Slot) -> bool:
        if job.pinned_slot is not None and job.pinned_slot != slot.sid:
            return False
        aff = job.group.slot_affinity
        return aff is None or slot.sid in aff

    def _fair_served_until(self, slot: Slot) -> float:
        return self.fair_until.get(slot.sid, 0.0)

    def task_slice(self, job: Job) -> float:
        if self._is_rt(job):
            # FIFO has no quantum; the 10 ms re-arm is the scheduler tick
            # (the task requeues at the *front*, so it runs to block), and it
            # is what gives RT-throttling its per-tick accounting.
            return self.quantum if self.quantum is not None else 0.010
        return FAIR_SLICE

    # --------------------------------------------------------------- enqueue
    def enqueue(self, job: Job, requeue: bool = False) -> None:
        if self._is_rt(job):
            self._enqueue_rt(job, requeue)
        else:
            self._enqueue_fair(job, requeue)

    def _enqueue_rt(self, job: Job, requeue: bool) -> None:
        kernel = self.kernel
        if requeue:
            slot = kernel.slots[job.prev_slot]
            if not slot.online:
                slot = self._find_lowest_rq(job) or kernel.online_slots()[0]
            if self.quantum is None:
                # FIFO: a preempted task resumes ahead of its queue.
                slot.local_dsq.push(job, -float(next(self._seq)))
            else:
                # RR: expired quantum -> tail of its slot's queue.
                slot.local_dsq.push(job, float(next(self._seq)))
            job.location = ("local", slot)
            if slot.current is None:
                kernel.kick(slot, preempt=False)
            return
        # Wakeup path: select_task_rq_rt analogue.
        prev = kernel.slots[job.prev_slot] if 0 <= job.prev_slot < len(kernel.slots) else None
        slot = None
        preempt = False
        if (prev is not None and prev.online and self._allowed(job, prev)
                and (prev.current is None or
                     (not self._is_rt(prev.current)
                      and kernel.now >= self._fair_served_until(prev)))):
            slot = prev
            preempt = prev.current is not None
        else:
            slot = self._find_lowest_rq(job)
            preempt = slot is not None and slot.current is not None
        if slot is None:
            # Everyone runs same-priority RT: stay on prev (or any allowed).
            slot = prev if prev is not None and prev.online and self._allowed(job, prev) \
                else next(s for s in kernel.online_slots() if self._allowed(job, s))
            preempt = False
        slot.local_dsq.push(job, float(next(self._seq)))
        job.location = ("local", slot)
        if slot.current is None:
            kernel.kick(slot, preempt=False)
        elif preempt:
            kernel.kick(slot, preempt=True)

    def _find_lowest_rq(self, job: Job):
        """cpupri analogue: an idle slot, else one running fair-class work
        (not inside a fair-server window)."""
        kernel = self.kernel
        for s in kernel.online_slots():
            if s.current is None and self._allowed(job, s):
                return s
        for s in kernel.online_slots():
            cur = s.current
            if (cur is not None and not self._is_rt(cur) and self._allowed(job, s)
                    and kernel.now >= self._fair_served_until(s)):
                return s
        return None

    def _enqueue_fair(self, job: Job, requeue: bool) -> None:
        kernel = self.kernel
        floor = self.fair_vmin - FAIR_SLICE * WEIGHT_SCALE
        if not requeue and job.vruntime < floor:
            job.vruntime = floor
        self.fair_queue.push(job, job.vruntime)
        job.location = ("fair", self)
        for slot in kernel.online_slots():
            if slot.idle and self._allowed(job, slot):
                kernel.kick(slot, preempt=False)
                return
        self._maybe_fair_serve()

    # -------------------------------------------------------------- dispatch
    def pick_next(self, slot: Slot):
        """During a fair-server window the slot serves the fair class first."""
        if self.kernel.now < self._fair_served_until(slot):
            job = slot.local_dsq.pop_first_where(
                lambda j: not self._is_rt(j) and j.state == JobState.RUNNABLE)
            if job is None:
                job = self.fair_queue.pop_first_where(
                    lambda j: j.state == JobState.RUNNABLE and self._allowed(j, slot))
            if job is not None:
                job.location = None
                return job
        return super().pick_next(slot)

    def dispatch(self, slot: Slot) -> None:
        kernel = self.kernel
        serving_fair = kernel.now < self._fair_served_until(slot)
        if not serving_fair:
            # pull_rt_task analogue: steal a queued, pushable RT task from an
            # overloaded slot before dropping to fair work.
            for other in kernel.online_slots():
                if other is slot or len(other.local_dsq) == 0:
                    continue
                if other.current is not None and any(
                        self._is_rt(j) for j in other.local_dsq.jobs()):
                    job = other.local_dsq.pop_first_where(
                        lambda j: (self._is_rt(j) and j.pinned_slot is None
                                   and j.state == JobState.RUNNABLE
                                   and self._allowed(j, slot)))
                    if job is not None:
                        job.prev_slot = slot.sid
                        slot.local_dsq.push(job, float(next(self._seq)))
                        job.location = ("local", slot)
                        kernel.metrics.lb_migrations += 1
                        return
        job = self.fair_queue.pop_first_where(
            lambda j: j.state == JobState.RUNNABLE and self._allowed(j, slot))
        if job is not None:
            slot.local_dsq.push(job, float("inf"))   # fair work sorts last
            job.location = ("local", slot)

    # ------------------------------------------------------------- charging
    def running(self, job: Job, slot: Slot) -> None:
        if not self._is_rt(job) and self.kernel.now < self._fair_served_until(slot):
            slot.slice_budget = min(slot.slice_budget,
                                    max(self._fair_served_until(slot) - self.kernel.now, 1e-4))

    def stopping(self, job: Job, slot: Slot, used: float) -> None:
        job.total_cpu += used
        job.group.usage_time += used
        job.last_ran = self.kernel.now
        if self._is_rt(job):
            self._account_rt(slot, used)
        else:
            job.vruntime += used * (WEIGHT_SCALE / max(job.group.effective_weight(), 1e-9))
            if job.vruntime > self.fair_vmin:
                self.fair_vmin = job.vruntime

    # ------------------------------------------------------- RT throttling
    def _account_rt(self, slot: Slot, used: float) -> None:
        """Rolling RT bandwidth control: once a slot has accumulated 95% of
        a window's worth of RT runtime since the last fair-server grant, it
        owes the fair class its 5% -- open a 50 ms grant if fair work is
        starved (Linux sched_rt_runtime_us / DL-server semantics)."""
        self.rt_since[slot.sid] = self.rt_since.get(slot.sid, 0.0) + used
        self._check_grant(slot)

    def _check_grant(self, slot: Slot) -> bool:
        if self.rt_since.get(slot.sid, 0.0) < RT_RUNTIME_FRAC * RT_WINDOW:
            return False
        if self.kernel.now < self._fair_served_until(slot):
            return False
        if not any(j.state == JobState.RUNNABLE and self._allowed(j, slot)
                   for j in self.fair_queue.jobs()):
            return False
        self.rt_since[slot.sid] = 0.0
        self.fair_until[slot.sid] = self.kernel.now + FAIR_BUDGET
        return True

    def _maybe_fair_serve(self) -> None:
        """A fair task became runnable with every slot saturated by RT:
        grant immediately on any slot that already owes the fair class."""
        for slot in self.kernel.online_slots():
            if self._check_grant(slot):
                if slot.current is not None and self._is_rt(slot.current):
                    self.kernel.kick(slot, preempt=True)
                return
