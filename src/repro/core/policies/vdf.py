"""VDF -- the EEVDF baseline analogue (paper sections 2, 3).

Faithfully models the mechanisms the paper identifies as EEVDF's failure
modes under mixed database workloads:

* **per-slot runqueues** ordered by *virtual deadline*
  (``vdeadline = vruntime + slice / weight``), weight-scaled charging,
  sleeper-credit clamping at wakeup;
* **run-to-parity**: a waking task does not preempt the current task; it
  waits for the current slice to finish (EEVDF's RUN_TO_PARITY default).
  Only idle-class current tasks are preempted immediately (see IdlePolicy);
* **wakeup placement**: previous slot if idle, else a *deterministic*
  idle-sibling scan from slot 0, else fall back to the previous slot. Since
  background work keeps most slots busy, bursty tasks repeatedly land on the
  few slots another bursty task just vacated -> pile-ups (paper Figure 2);
* **gated newidle balancing**: a slot going idle pulls queued work from the
  busiest runqueue *only if* its average idle period exceeds the migration
  cost -- bursty tasks' sub-millisecond sleeps fail the gate, so pile-ups
  are not corrected at idle time;
* **periodic load balancing** every ``lb_interval`` using PELT-style
  decaying per-slot load averages; migrates one *queued* task that is not
  cache-hot (ran within MIGRATION_COST) from the most- to the least-loaded
  runqueue. Because bursty tasks are queued only briefly (and are usually
  cache-hot when they are), the periodic balancer mostly evacuates the
  long-queued low-weight background tasks -- which is exactly what empties
  bursty slots and feeds the placement pathology, while only *eventually*
  correcting bursty pile-ups (paper: "By the time load-balancing kicks in,
  throughput has already been impacted").
"""
from __future__ import annotations

from ..kernel import Policy, Slot
from ..task import Job, JobState
from ..vruntime import WEIGHT_SCALE

BASE_SLICE = 0.0015          # EEVDF base slice analogue
SLEEPER_CREDIT = 0.0015      # wakeup vruntime clamp (sched_latency analogue)
MIGRATION_COST = 0.0005      # newidle gate + cache-hot filter (0.5 ms)
LB_INTERVAL = 0.008          # periodic load-balance cadence
PELT_DECAY = 0.6             # per-tick decay of the load average


class VDFPolicy(Policy):
    name = "vdf"
    periodic_interval = LB_INTERVAL

    def __init__(self, base_slice: float = BASE_SLICE):
        self.base_slice = base_slice
        self.rq_vmin: dict[int, float] = {}
        self.idle_ewma: dict[int, float] = {}
        self.idle_since: dict[int, float] = {}
        self.load_avg: dict[int, float] = {}     # PELT-style slot load
        self.util_avg: dict[int, float] = {}     # PELT-style slot utilization
        self.win_wsec: dict[int, float] = {}     # weight-seconds this LB window
        self.win_busy: dict[int, float] = {}     # busy-seconds this LB window
        self._fallback_cursor = 0
        self._lb_fails = 0                       # active-balance escalation

    # ------------------------------------------------------------------
    def task_slice(self, job: Job) -> float:
        return self.base_slice

    def _weight(self, job: Job) -> float:
        return max(job.group.effective_weight(), 1e-9)

    def _deadline(self, job: Job) -> float:
        return job.vruntime + self.base_slice * (WEIGHT_SCALE / self._weight(job))

    def _preempts(self, new: Job, cur: Job) -> bool:
        return False          # RUN_TO_PARITY: wait for the current slice

    # --------------------------------------------------------------- enqueue
    def enqueue(self, job: Job, requeue: bool = False) -> None:
        kernel = self.kernel
        if requeue and kernel.slots[job.prev_slot].online:
            # Slice expiry / preemption: stay on the current runqueue.
            slot = kernel.slots[job.prev_slot]
        else:
            slot = self._place(job)
            # Sleeper credit: clamp vruntime near the rq's min (CFS-style,
            # unscaled constant credit).
            floor = self.rq_vmin.get(slot.sid, 0.0) - SLEEPER_CREDIT
            if job.vruntime < floor:
                job.vruntime = floor
        job.vdeadline = self._deadline(job)
        slot.local_dsq.push(job, job.vdeadline)
        job.location = ("local", slot)
        if slot.current is None:
            kernel.kick(slot, preempt=False)
        elif not requeue and self._preempts(job, slot.current):
            kernel.kick(slot, preempt=True)

    def _place(self, job: Job) -> Slot:
        """EEVDF wakeup placement (see module docstring).

        1. previous slot if idle (wake_affine_idle);
        2. wake-affine: wakeups delivered by another slot (the network-RX
           IRQ slot, for TPC-C-over-TCP backends) pull the wakee toward the
           waker's slot when it is not overloaded;
        3. deterministic idle-sibling scan from the target;
        4. fall back to the target (queue there).
        Steps 2-4 are what stack bursty tasks onto the few briefly-idle
        slots (paper Figure 2's staircase).
        """
        kernel = self.kernel
        slots = kernel.online_slots()
        if job.pinned_slot is not None:
            return kernel.slots[job.pinned_slot]
        prev = kernel.slots[job.prev_slot] if 0 <= job.prev_slot < len(kernel.slots) else None
        if prev is not None and prev.online and prev.idle:
            return prev
        target = prev
        if job.waker_slot is not None:
            waker = kernel.slots[job.waker_slot]
            # wake_affine: pull toward the waker's slot only when it is no
            # more loaded than prev (CFS compares load averages).
            if (waker.online and len(waker.local_dsq) == 0
                    and (prev is None or self.load_avg.get(waker.sid, 0.0)
                         <= self.load_avg.get(prev.sid, 0.0))):
                target = waker
        # Deterministic idle-sibling scan from the target slot. SIS_UTIL:
        # scan depth shrinks with average utilization -- under a saturating
        # background load the scan is skipped entirely and wakeups fall back
        # to the target, stacking bursty tasks (paper Figure 2).
        start = target.sid if target is not None else 0
        n = len(kernel.slots)
        avg_util = (sum(self.util_avg.get(s.sid, 0.0) for s in slots)
                    / max(len(slots), 1))
        depth = min(n, int(round(n * max(0.0, 1.0 - avg_util) * 1.5)))
        for i in range(depth):
            s = kernel.slots[(start + i) % n]
            if s.online and self._scan_idle(s):
                return s
        # Scan failed: fall back to the target slot (queue there).
        if target is not None and target.online:
            return target
        if prev is not None and prev.online:
            return prev
        # No previous slot (fork/exec placement): least-loaded, rotating ties.
        n = len(slots)
        self._fallback_cursor = (self._fallback_cursor + 1) % n
        order = slots[self._fallback_cursor:] + slots[:self._fallback_cursor]
        return min(order, key=lambda s: self.load_avg.get(s.sid, 0.0))

    def _scan_idle(self, slot: Slot) -> bool:
        """Does the idle-sibling scan consider this slot idle?"""
        return slot.idle

    # -------------------------------------------------------------- dispatch
    def dispatch(self, slot: Slot) -> None:
        """Local rq empty -> newidle balance, gated on average idle period."""
        now = self.kernel.now
        if self.idle_ewma.get(slot.sid, 1.0) >= MIGRATION_COST:
            busiest = max((s for s in self.kernel.online_slots() if s is not slot),
                          key=lambda s: len(s.local_dsq), default=None)
            if busiest is not None and len(busiest.local_dsq) > 0:
                job = self._detach_one(busiest)
                if job is not None:
                    self.kernel.metrics.lb_migrations += 1
                    job.prev_slot = slot.sid
                    slot.local_dsq.push(job, job.vdeadline)
                    job.location = ("local", slot)
                    return
        self.idle_since[slot.sid] = now

    def _detach_one(self, rq: Slot):
        """Pick a migratable queued task: not pinned, runnable, not cache-hot."""
        now = self.kernel.now
        return rq.local_dsq.pop_first_where(
            lambda j: (j.pinned_slot is None and j.state == JobState.RUNNABLE
                       and now - getattr(j, "last_ran", -1.0) >= MIGRATION_COST))

    def running(self, job: Job, slot: Slot) -> None:
        start = self.idle_since.pop(slot.sid, None)
        if start is not None:
            dur = self.kernel.now - start
            prev = self.idle_ewma.get(slot.sid, 1.0)
            self.idle_ewma[slot.sid] = 0.75 * prev + 0.25 * dur

    def stopping(self, job: Job, slot: Slot, used: float) -> None:
        job.vruntime += used * (WEIGHT_SCALE / self._weight(job))
        job.total_cpu += used
        job.group.usage_time += used
        job.last_ran = self.kernel.now
        self.win_wsec[slot.sid] = self.win_wsec.get(slot.sid, 0.0) + self._weight(job) * used
        self.win_busy[slot.sid] = self.win_busy.get(slot.sid, 0.0) + used
        vmin = self.rq_vmin.get(slot.sid, 0.0)
        if job.vruntime > vmin:
            self.rq_vmin[slot.sid] = job.vruntime

    # -------------------------------------------------------------- periodic
    def periodic(self) -> None:
        """Update PELT loads; move one cold queued task busiest -> idlest."""
        slots = self.kernel.online_slots()
        for s in slots:
            w = self.win_wsec.pop(s.sid, 0.0) / LB_INTERVAL
            self.load_avg[s.sid] = PELT_DECAY * self.load_avg.get(s.sid, 0.0) \
                + (1.0 - PELT_DECAY) * w
            b = min(1.0, self.win_busy.pop(s.sid, 0.0) / LB_INTERVAL)
            self.util_avg[s.sid] = PELT_DECAY * self.util_avg.get(s.sid, 0.0) \
                + (1.0 - PELT_DECAY) * b
        if len(slots) < 2:
            return
        busiest = max(slots, key=lambda s: self.load_avg.get(s.sid, 0.0))
        idlest = min(slots, key=lambda s: self.load_avg.get(s.sid, 0.0))
        if busiest is idlest or len(busiest.local_dsq) == 0:
            return
        if self.load_avg.get(busiest.sid, 0.0) <= 1.25 * self.load_avg.get(idlest.sid, 0.0):
            return
        job = self._detach_one(busiest)
        if job is None:
            # active balance: after repeated failures, migrate even a
            # cache-hot queued task (CFS nr_balance_failed escalation).
            self._lb_fails += 1
            if self._lb_fails < 3:
                return
            job = busiest.local_dsq.pop_first_where(
                lambda j: j.pinned_slot is None and j.state == JobState.RUNNABLE)
            if job is None:
                return
        self._lb_fails = 0
        self.kernel.metrics.lb_migrations += 1
        job.prev_slot = idlest.sid
        job.vdeadline = self._deadline(job)
        idlest.local_dsq.push(job, job.vdeadline)
        job.location = ("local", idlest)
        if idlest.current is None:
            self.kernel.kick(idlest, preempt=False)
