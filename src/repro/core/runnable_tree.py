"""The runnable tree (paper section 5.1.3, Figure 5).

Manages runnable *background* group queues, keyed by group virtual runtime.
The paper implements this as an eBPF red-black tree; here we use a binary
heap with lazy invalidation, which preserves the verifier-friendly contract
(bounded peek/remove/insert, no unbounded traversal) and gives the same
O(log n) operations:

* ``insert(group)``   -- (re)insert a group keyed by its current vruntime
* ``peek_min()``      -- group with the lowest vruntime (leftmost leaf)
* ``remove(group)``   -- drop a group (e.g. it became empty -> stashed)

A per-group epoch counter invalidates stale heap entries, mirroring how the
paper removes vanished cgroups during dispatch ("Verify active state").
The *stash* for empty groups' bookkeeping nodes is modelled by simply
dropping membership; re-insert is O(log n).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Optional

from .task import WorkloadGroup


class RunnableTree:
    def __init__(self) -> None:
        self._heap: list[tuple[float, int, int, WorkloadGroup]] = []
        self._seq = itertools.count()
        self._epoch = itertools.count()
        self._members: dict[int, int] = {}    # gid -> live epoch

    def __len__(self) -> int:
        return len(self._members)

    def __contains__(self, group: WorkloadGroup) -> bool:
        return group.gid in self._members

    def insert(self, group: WorkloadGroup) -> None:
        """Insert or re-key ``group`` at its current ``group.vruntime``."""
        epoch = next(self._epoch)
        self._members[group.gid] = epoch
        group.tree_epoch = epoch
        heapq.heappush(self._heap, (group.vruntime, next(self._seq), epoch, group))

    def remove(self, group: WorkloadGroup) -> None:
        """Remove ``group`` (lazy: stale heap entries are skipped on peek)."""
        self._members.pop(group.gid, None)

    def peek_min(self) -> Optional[WorkloadGroup]:
        """Group with the minimum vruntime, or None if the tree is empty."""
        heap = self._heap
        while heap:
            vrt, _, epoch, group = heap[0]
            if self._members.get(group.gid) == epoch and group.vruntime == vrt:
                return group
            heapq.heappop(heap)   # stale (removed or re-keyed) -- discard
        return None

    def pop_min(self) -> Optional[WorkloadGroup]:
        group = self.peek_min()
        if group is not None:
            self.remove(group)
        return group

    def min_vruntime(self) -> float:
        g = self.peek_min()
        return g.vruntime if g is not None else 0.0
