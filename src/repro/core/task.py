"""Schedulable entities: jobs, workload groups (cgroup analogue), tiers.

Maps the paper's vocabulary onto the TPU-pod adaptation (DESIGN.md section 2):

* ``Tier``            -- the two UFS scheduling tiers (time-sensitive / background).
* ``WorkloadGroup``   -- cgroup analogue: hierarchical, weighted, with optional
                         rate caps (``cpu.max``) and slot affinity (``cpuset``).
* ``Job``             -- a "task" in paper terms: a process/backend emitting a
                         stream of bounded execution phases (bursts / blocks).

In sim mode a job's behaviour is a generator of :class:`Phase` objects; in live
mode the job wraps a callable that executes a real (JAX) chunk per dispatch.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional


@dataclass(frozen=True)
class RetryPolicy:
    """Per-job restart policy applied by the panic path (DESIGN.md
    section 12): a faulted job is restarted up to ``max_retries`` times
    with exponential backoff, then quarantined (EXITED, ``quarantined``
    set) so a crash-looping job can never occupy the scheduler forever.
    Jobs without a policy quarantine on the first panic."""

    max_retries: int = 3
    backoff: float = 0.005          # delay before the first restart
    backoff_growth: float = 2.0
    max_backoff: float = 0.25

    def delay(self, attempt: int) -> float:
        """Restart delay before retry ``attempt`` (1-based)."""
        return min(self.backoff * self.backoff_growth ** (attempt - 1),
                   self.max_backoff)


class Tier(enum.IntEnum):
    """UFS scheduling tiers. Lower value = higher precedence."""

    TIME_SENSITIVE = 0
    BACKGROUND = 1


class JobState(enum.Enum):
    NEW = "new"
    RUNNABLE = "runnable"      # waiting in a DSQ
    RUNNING = "running"        # occupying a slot
    BLOCKED = "blocked"        # sleeping (I/O, client think, lock backoff)
    LOCK_WAIT = "lock_wait"    # parked on an engine lock
    EXITED = "exited"


# Default cgroup cpu.weight in Linux.
DEFAULT_WEIGHT = 100.0
MIN_WEIGHT = 1.0
MAX_WEIGHT = 10_000.0


_group_ids = itertools.count()


class WorkloadGroup:
    """Hierarchical workload group -- the cgroup analogue.

    ``weight`` follows cgroup ``cpu.weight`` semantics (1..10000, default 100);
    the *effective* weight of a group resolves relative to its siblings through
    the hierarchy, as in the paper ("each cgroup's parameters are defined
    relative to its parent, with changes propagating accordingly").
    """

    def __init__(
        self,
        name: str,
        tier: Tier,
        weight: float = DEFAULT_WEIGHT,
        parent: Optional["WorkloadGroup"] = None,
        rate_cap: Optional[float] = None,
        slot_affinity: Optional[frozenset] = None,
    ):
        if not (MIN_WEIGHT <= weight <= MAX_WEIGHT):
            raise ValueError(f"weight {weight} outside [{MIN_WEIGHT}, {MAX_WEIGHT}]")
        self.gid = next(_group_ids)
        self.name = name
        self.tier = tier
        self.weight = float(weight)
        self.parent = parent
        self.children: list[WorkloadGroup] = []
        if parent is not None:
            if parent.tier != tier:
                raise ValueError("child group must share its parent's tier")
            parent.children.append(self)
        self.rate_cap = rate_cap                  # fraction of total slot-time, cpu.max analogue
        self.slot_affinity = slot_affinity        # cpuset analogue
        # --- scheduler state (owned by the policy) ---
        self.vruntime: float = 0.0                # group virtual runtime (runnable-tree key)
        self.task_vmax: float = 0.0               # high-watermark of member task vruntimes
        self.last_active: float = 0.0             # last dispatch charge (clamp gating)
        self.tree_epoch: int = -1                 # runnable-tree membership version
        self.usage_time: float = 0.0              # raw slot-seconds consumed (rate cap / metrics)

    # -- hierarchy -----------------------------------------------------------
    def effective_weight(self) -> float:
        """Weight resolved through the hierarchy: a child's share scales by its
        fraction of the sibling weight mass under its parent."""
        if self.parent is None:
            return self.weight
        sibling_mass = sum(c.weight for c in self.parent.children) or 1.0
        return self.parent.effective_weight() * (self.weight / sibling_mass)

    def set_weight(self, weight: float) -> None:
        """Dynamic reconfiguration (cgroup echo > cpu.weight analogue)."""
        if not (MIN_WEIGHT <= weight <= MAX_WEIGHT):
            raise ValueError(f"weight {weight} outside [{MIN_WEIGHT}, {MAX_WEIGHT}]")
        self.weight = float(weight)

    def __repr__(self) -> str:  # pragma: no cover
        return f"WorkloadGroup({self.name!r}, {self.tier.name}, w={self.weight})"


# ---------------------------------------------------------------------------
# Sim-mode job behaviour phases
# ---------------------------------------------------------------------------

@dataclass
class Burst:
    """Wants the execution unit for ``duration`` seconds (preemptible)."""

    duration: float
    request_id: Optional[int] = None   # if set, completing this burst completes a request


@dataclass
class Block:
    """Sleeps off-CPU for ``duration`` seconds (I/O, client think, backoff)."""

    duration: float


@dataclass
class TryLock:
    """Zero-time lock poll; the kernel ``send()``s back True/False. Used by
    the spin-acquire helper (``core.locks.spin_acquire``), whose CPU poll
    cost is modelled by a preceding :class:`Burst`."""

    lock: object          # core.locks.SimLock


@dataclass
class AcquireLock:
    """Sleep-discipline acquisition (LWLock analogue): park until hand-off."""

    lock: object


@dataclass
class ReleaseLock:
    lock: object


@dataclass
class PanicExit:
    """Stuck-spinlock watchdog fired (PostgreSQL PANIC analogue)."""


@dataclass
class RequestBegin:
    """Marks the client-visible start of a request (latency accounting)."""


@dataclass
class RequestEnd:
    pass


@dataclass
class Exit:
    pass


Phase = object  # union of the dataclasses above

_job_ids = itertools.count(1)


class Job:
    """A schedulable job.

    Sim mode: ``behavior`` is an iterator of phases. Live mode: ``run_chunk``
    is a callable ``(budget_s) -> (used_s, done)`` executing one bounded chunk
    of real work (e.g. a training microbatch or a decode iteration).
    """

    def __init__(
        self,
        group: WorkloadGroup,
        behavior: Optional[Iterator[Phase]] = None,
        run_chunk: Optional[Callable[[float], tuple]] = None,
        name: Optional[str] = None,
        kind: str = "generic",
        behavior_factory: Optional[Callable[[], Iterator[Phase]]] = None,
        retry_policy: Optional[RetryPolicy] = None,
    ):
        self.jid = next(_job_ids)
        self.name = name or f"job{self.jid}"
        self.kind = kind                      # "bursty" / "bound" / ... for metrics
        self.group = group
        self.behavior_factory = behavior_factory
        if behavior is None and behavior_factory is not None:
            behavior = behavior_factory()
        self.behavior = behavior
        self.run_chunk = run_chunk
        self.state = JobState.NEW
        # --- scheduler state ---
        self.vruntime: float = 0.0            # task virtual runtime (weight-scaled)
        self.prev_slot: int = -1              # last slot this job ran on
        self.boosted: bool = False            # hint-based priority-inversion boost
        self.boost_group = None               # TS group whose priority is inherited
        self.boost_count: int = 0             # times boosted (metrics / tests)
        self.pinned_slot: Optional[int] = None  # taskset analogue
        self.waker_slot: Optional[int] = None   # slot delivering wakeups (network RX IRQ)
        self.last_ran: float = -1.0             # cache-hot tracking (LB filters)
        self.location: Optional[tuple] = None   # ("local", slot)|("group", group) while queued
        # RT-class attributes for FIFO/RR baselines
        self.rt_priority: int = 0
        self.vdeadline: float = 0.0             # VDF baseline state
        # --- sim execution state ---
        self.burst_remaining: float = 0.0
        self.current_request: Optional[int] = None
        self.request_started_at: float = 0.0
        self.wakeup_time: float = 0.0         # when the job last became runnable
        self.resume_value = None              # value sent into the generator on resume
        self.total_cpu: float = 0.0
        self.completed_requests: int = 0
        self.held_locks: set = set()
        # --- fault containment state (DESIGN.md section 12) ---
        self.panic: bool = False              # a panic path fired for this job
        self.retry_policy = retry_policy      # None -> quarantine on first panic
        self.retries: int = 0                 # restarts consumed so far
        self.quarantined: bool = False        # EXITED via the quarantine path
        self.last_panic: str = ""             # repr of the last fault cause

    # Effective tier seen by the scheduler (boost lifts BG jobs into TS).
    @property
    def tier(self) -> Tier:
        if self.boosted:
            return Tier.TIME_SENSITIVE
        return self.group.tier

    def sched_group(self) -> WorkloadGroup:
        """Group used for scheduling/charging: a boosted job inherits the
        waiting time-sensitive task's group (priority inheritance)."""
        if self.boosted and self.boost_group is not None:
            return self.boost_group
        return self.group

    def __repr__(self) -> str:  # pragma: no cover
        return f"Job({self.name}, {self.group.name}, {self.state.value})"
