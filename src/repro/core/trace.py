"""Scheduler tracing: structured lifecycle events from the core.

The userspace analogue of the paper's eBPF tracepoints (section 6.1
reconstructs per-CPU execution timelines from ``sched_switch`` events;
"Silentium!" argues DB/OS interference is only diagnosable at this event
granularity).  :class:`SchedTracer` is a bounded ring buffer the
:class:`~repro.core.base.SchedCore` emits :class:`TraceEvent` records into
at every lifecycle edge -- wake, enqueue, dispatch, start/stop, preempt,
kick, boost/unboost, lock acquire/release with holder identity, slot
add/drain.  The schema is backend-agnostic: sim and live runs produce the
same event stream, timestamped by their respective clocks, so every
derived analysis below works identically on both.

On top of the raw stream:

* :func:`to_chrome_trace` / :func:`write_chrome_trace` -- Chrome
  ``trace_event`` JSON (one track per slot, one per group, one per lock;
  instant events for kicks and boosts), loadable at https://ui.perfetto.dev;
* :func:`busy_intervals` / :func:`slot_busy_from_trace` -- per-slot busy
  timelines, reproducing the paper's Figure 2 from the trace instead of
  charge-time accounting (cross-checked against ``Metrics`` in
  tests/test_trace.py);
* :func:`wakeup_delays` -- wakeup-latency breakdown per group;
* :func:`detect_inversions` -- priority-inversion spans with boost
  resolution time (boost -> unboost per holder);
* :class:`TraceSummary` -- counters the parity benchmark diffs across
  backends (benchmarks/parity.py).

``python -m repro.core.trace --out trace.json`` runs a small mixed
workload in simulation, validates the exported trace against the schema,
and writes it -- CI uploads this file as a workflow artifact.
"""
from __future__ import annotations

import json
import math
import threading
from collections import Counter, defaultdict, deque
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import ContextManager, Iterable, Optional

__all__ = [
    "EVENT_KINDS", "TraceEvent", "SchedTracer", "TraceSummary", "summarize",
    "busy_intervals", "slot_busy_from_trace", "wakeup_delays",
    "detect_inversions", "to_chrome_trace", "write_chrome_trace",
    "validate_events", "validate_chrome_trace", "TraceSchemaError",
]

#: Every lifecycle edge the core emits.  Kept in one frozenset so schema
#: validation and tests cannot drift from the emitters.
EVENT_KINDS = frozenset({
    "wake",            # job became runnable (first cause of a dispatch chain)
    "enqueue",         # handed to the policy (args: requeue)
    "dispatch",        # slot pulled from the policy (local DSQ was empty)
    "start_job",       # job began running on a slot
    "stop_job",        # job left a slot (args: used, reason)
    "preempt_slot",    # running job forced off a slot
    "kick",            # slot kicked (args: preempt)
    "boost",           # hint boost: BG lock holder lifted into the TS tier
    "unboost",         # boost released (lock freed)
    "lock_wait",       # contended lock (args: lock, lock_id, holder identity)
    "lock_acquire",    # lock granted (args: lock, lock_id)
    "lock_release",    # lock released
    "slot_add",        # elastic scale-up
    "slot_drain",      # slot taken offline
    "lock_timeout",    # acquire gave up waiting (args: lock, lock_id)
    "panic",           # job faulted (args: reason, error, traceback, retries)
    "retry",           # panic path restarting the job (args: attempt, delay)
    "quarantine",      # retries exhausted: job poisoned to EXITED
    "park",            # idle worker parked on its per-slot event (live only)
    "unpark",          # parked worker woken (args: waited)
})

DEFAULT_CAPACITY = 1 << 16


class TraceSchemaError(ValueError):
    """An event stream or exported trace violates the schema."""


@dataclass(frozen=True, slots=True)
class TraceEvent:
    """One structured scheduler event.  ``slot``/``jid`` are -1 when the
    event is not slot- or job-scoped; ``args`` holds kind-specific fields
    (used, reason, lock, preempt, ...)."""

    t: float
    kind: str
    slot: int = -1
    jid: int = -1
    job: str = ""
    group: str = ""
    jkind: str = ""
    args: Optional[dict] = None

    def to_dict(self) -> dict:
        d = {"t": self.t, "kind": self.kind}
        if self.slot >= 0:
            d["slot"] = self.slot
        if self.jid >= 0:
            d.update(jid=self.jid, job=self.job, group=self.group,
                     jkind=self.jkind)
        if self.args:
            d["args"] = dict(self.args)
        return d


class SchedTracer:
    """Bounded ring buffer of :class:`TraceEvent`.

    Backend-agnostic: the emitter passes the timestamp explicitly (virtual
    clock in sim, monotonic in live).  Appends are guarded by a mutex so
    live-mode paths that emit outside the core guard (``LiveLock``) stay
    consistent; when the ring wraps, the oldest events are dropped and
    counted in :attr:`dropped`.

    ``kinds`` optionally restricts retention to a subset of
    :data:`EVENT_KINDS` (e.g. only ``start_job``/``stop_job`` for long
    busy-timeline captures).
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 kinds: Optional[Iterable[str]] = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.kinds = frozenset(kinds) if kinds is not None else None
        if self.kinds is not None and not self.kinds <= EVENT_KINDS:
            raise ValueError(f"unknown kinds {sorted(self.kinds - EVENT_KINDS)}")
        self._events: deque = deque(maxlen=capacity)
        self._emitted = 0
        self._mu: ContextManager = threading.Lock()

    def set_threadsafe(self, threadsafe: bool) -> None:
        """Swap the append mutex for a no-op guard (or back).

        The sim backend is a single-threaded event loop, so the core calls
        ``set_threadsafe(False)`` at attach time and every emit skips the
        lock; live mode keeps the real mutex because ``LiveLock`` paths
        emit outside the core guard."""
        self._mu = threading.Lock() if threadsafe else nullcontext()

    # ------------------------------------------------------------------
    def emit(self, kind: str, t: float, slot: Optional[int] = None,
             job=None, **args) -> None:
        """Record one event.  ``job`` is any Job-like object (``jid``,
        ``name``, ``kind``, ``group.name``); extra keywords become
        ``args``."""
        if self.kinds is not None and kind not in self.kinds:
            return
        ev = TraceEvent(
            t=t, kind=kind,
            slot=slot if slot is not None else -1,
            jid=job.jid if job is not None else -1,
            job=job.name if job is not None else "",
            group=job.group.name if job is not None else "",
            jkind=job.kind if job is not None else "",
            args=args or None,
        )
        with self._mu:
            self._emitted += 1
            self._events.append(ev)

    # ------------------------------------------------------------------
    @property
    def events(self) -> list:
        with self._mu:
            return list(self._events)

    @property
    def emitted(self) -> int:
        return self._emitted

    @property
    def dropped(self) -> int:
        with self._mu:
            return self._emitted - len(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        with self._mu:
            self._events.clear()
            self._emitted = 0

    def summary(self) -> "TraceSummary":
        with self._mu:
            evs = list(self._events)
            dropped = self._emitted - len(evs)
        return summarize(evs, dropped=dropped)


# ---------------------------------------------------------------------------
# Summary
# ---------------------------------------------------------------------------

@dataclass
class TraceSummary:
    """Counters over an event stream -- the unit the parity benchmark diffs
    across backends and :class:`~repro.core.build.KernelReport` embeds."""

    events: int = 0
    dropped: int = 0
    t0: float = 0.0
    t1: float = 0.0
    counts: dict = field(default_factory=dict)        # kind -> n
    inversions: int = 0                               # boost spans seen
    inversions_resolved: int = 0                      # ... that unboosted
    max_boost_resolution: float = 0.0                 # slowest inversion fix

    def counters(self) -> dict:
        out = {k: self.counts.get(k, 0) for k in sorted(EVENT_KINDS)}
        out.update(events=self.events, dropped=self.dropped,
                   inversions=self.inversions,
                   inversions_resolved=self.inversions_resolved)
        return out

    def to_dict(self) -> dict:
        return {
            "events": self.events, "dropped": self.dropped,
            "span": [self.t0, self.t1],
            "counts": {k: self.counts[k] for k in sorted(self.counts)},
            "inversions": self.inversions,
            "inversions_resolved": self.inversions_resolved,
            "max_boost_resolution": self.max_boost_resolution,
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    def diff(self, other: "TraceSummary") -> dict:
        """Presence diff against another backend's summary: kinds one stream
        has and the other lacks.  Absolute counts are never comparable
        across clocks, presence must be (the parity invariant)."""
        mine, theirs = self.counters(), other.counters()
        out = {}
        for k in sorted(EVENT_KINDS):
            if (mine[k] > 0) != (theirs[k] > 0):
                out[k] = (mine[k], theirs[k])
        return out


def summarize(events: list, dropped: int = 0) -> TraceSummary:
    counts = Counter(ev.kind for ev in events)
    inv = detect_inversions(events)
    resolved = [i for i in inv if i["resolution"] is not None]
    return TraceSummary(
        events=len(events), dropped=dropped,
        t0=events[0].t if events else 0.0,
        t1=events[-1].t if events else 0.0,
        counts=dict(counts),
        inversions=len(inv),
        inversions_resolved=len(resolved),
        max_boost_resolution=max((i["resolution"] for i in resolved),
                                 default=0.0),
    )


# ---------------------------------------------------------------------------
# Derived analyses
# ---------------------------------------------------------------------------

def busy_intervals(events: list, end: Optional[float] = None) -> dict:
    """Per-slot execution timeline: ``{slot: [interval, ...]}`` where each
    interval is ``{"start", "stop", "jid", "job", "group", "jkind",
    "reason"}`` -- the Figure-2 reconstruction, built from
    ``start_job``/``stop_job`` pairs exactly as the paper rebuilds per-CPU
    timelines from ``sched_switch``.  A job still running at the end of the
    stream is closed at ``end`` (when given), mirroring the kernel's
    horizon settlement."""
    out: dict = defaultdict(list)
    open_: dict = {}
    for ev in events:
        if ev.kind == "start_job":
            open_[ev.slot] = ev
        elif ev.kind == "stop_job":
            started = open_.pop(ev.slot, None)
            if started is not None:
                out[ev.slot].append({
                    "start": started.t, "stop": ev.t,
                    "jid": ev.jid, "job": ev.job, "group": ev.group,
                    "jkind": ev.jkind,
                    "reason": (ev.args or {}).get("reason", ""),
                })
    if end is not None:
        for slot, started in open_.items():
            out[slot].append({
                "start": started.t, "stop": max(end, started.t),
                "jid": started.jid, "job": started.job,
                "group": started.group, "jkind": started.jkind,
                "reason": "open",
            })
    return dict(out)


def slot_busy_from_trace(events: list, n_slots: int, kind: str = "",
                         window: tuple = (0.0, 0.0),
                         end: Optional[float] = None) -> list:
    """Per-slot busy seconds from the trace, clipped to ``window`` --
    directly comparable to ``Metrics.slot_utilization(kind, n_slots)``."""
    ws, we = window
    hi_bound = we if we > 0.0 else math.inf
    busy = [0.0] * n_slots
    for slot, ivs in busy_intervals(events, end=end).items():
        if not (0 <= slot < n_slots):
            continue
        for iv in ivs:
            if kind and iv["jkind"] != kind:
                continue
            lo = min(max(iv["start"], ws), hi_bound)
            hi = min(max(iv["stop"], ws), hi_bound)
            busy[slot] += hi - lo
    return busy


def wakeup_delays(events: list) -> dict:
    """Per-group wake -> first-start delays (the paper's wakeup-latency
    attribution for tail spikes).  Matches the metrics convention: only the
    first start after each wake counts."""
    pending: dict = {}
    delays: dict = defaultdict(list)
    for ev in events:
        if ev.kind == "wake":
            pending[ev.jid] = ev.t
        elif ev.kind == "start_job" and ev.jid in pending:
            delays[ev.group].append(ev.t - pending.pop(ev.jid))
    return dict(delays)


def detect_inversions(events: list) -> list:
    """Priority-inversion spans: each hint boost of a background lock
    holder, paired with its unboost.  ``resolution`` is the boost->unboost
    time (how long the inversion took to resolve once detected); None for
    spans still open at the end of the stream."""
    open_: dict = {}
    out = []
    for ev in events:
        if ev.kind == "boost":
            open_[ev.jid] = ev
        elif ev.kind == "unboost":
            b = open_.pop(ev.jid, None)
            if b is not None:
                out.append({
                    "jid": ev.jid, "job": ev.job, "group": b.group,
                    "boost_group": (b.args or {}).get("boost_group", ""),
                    "t_boost": b.t, "t_unboost": ev.t,
                    "resolution": ev.t - b.t,
                })
    for b in open_.values():
        out.append({
            "jid": b.jid, "job": b.job, "group": b.group,
            "boost_group": (b.args or {}).get("boost_group", ""),
            "t_boost": b.t, "t_unboost": None, "resolution": None,
        })
    out.sort(key=lambda i: i["t_boost"])
    return out


# ---------------------------------------------------------------------------
# Chrome trace_event export
# ---------------------------------------------------------------------------

PID_SLOTS, PID_GROUPS, PID_LOCKS = 1, 2, 3


def _us(t: float) -> float:
    return round(t * 1e6, 3)


def to_chrome_trace(events: list, end: Optional[float] = None) -> dict:
    """Export to the Chrome ``trace_event`` JSON object format (loadable in
    Perfetto / chrome://tracing).

    Layout: process "slots" has one thread per slot carrying complete
    ("X") events per job run plus instant events for kicks and preempts;
    process "groups" has one thread per workload group carrying the same
    runs grouped by owner plus instant wake/boost/unboost events; process
    "locks" has one thread per lock with held spans named by holder."""
    te: list = []
    slots_seen: list = []
    groups_seen: list = []

    def group_tid(name: str) -> int:
        if name not in groups_seen:
            groups_seen.append(name)
        return groups_seen.index(name)

    def meta(pid: int, name: str) -> None:
        te.append({"ph": "M", "pid": pid, "tid": 0, "ts": 0,
                   "name": "process_name", "args": {"name": name}})

    meta(PID_SLOTS, "slots")
    meta(PID_GROUPS, "groups")
    meta(PID_LOCKS, "locks")

    # --- run spans: slot tracks and group tracks -----------------------
    for slot, ivs in sorted(busy_intervals(events, end=end).items()):
        if slot not in slots_seen:
            slots_seen.append(slot)
        for iv in ivs:
            common = {
                "name": iv["job"], "cat": iv["jkind"] or "job", "ph": "X",
                "ts": _us(iv["start"]),
                "dur": max(0.0, _us(iv["stop"]) - _us(iv["start"])),
                "args": {"jid": iv["jid"], "group": iv["group"],
                         "reason": iv["reason"], "slot": slot},
            }
            te.append(dict(common, pid=PID_SLOTS, tid=slot))
            te.append(dict(common, pid=PID_GROUPS, tid=group_tid(iv["group"])))

    # --- instant events and lock spans ---------------------------------
    open_locks: dict = {}
    for ev in events:
        a = ev.args or {}
        if ev.kind in ("kick", "preempt_slot", "park", "unpark"):
            te.append({"name": ev.kind, "ph": "i", "s": "t",
                       "pid": PID_SLOTS, "tid": ev.slot, "ts": _us(ev.t),
                       "args": {k: v for k, v in a.items()}})
            if ev.slot not in slots_seen:
                slots_seen.append(ev.slot)
        elif ev.kind in ("wake", "boost", "unboost",
                         "panic", "retry", "quarantine"):
            te.append({"name": ev.kind, "ph": "i", "s": "t",
                       "pid": PID_GROUPS, "tid": group_tid(ev.group),
                       "ts": _us(ev.t), "args": dict(a, job=ev.job)})
        elif ev.kind == "lock_acquire":
            open_locks[a.get("lock_id", -1)] = ev
        elif ev.kind == "lock_release":
            got = open_locks.pop(a.get("lock_id", -1), None)
            if got is not None:
                ga = got.args or {}
                te.append({
                    "name": f"{ga.get('lock', 'lock')}:{got.job}",
                    "cat": "lock", "ph": "X", "pid": PID_LOCKS,
                    "tid": ga.get("lock_id", 0), "ts": _us(got.t),
                    "dur": max(0.0, _us(ev.t) - _us(got.t)),
                    "args": {"holder": got.job, "holder_jid": got.jid},
                })
        elif ev.kind == "lock_wait":
            te.append({"name": f"wait:{a.get('lock', 'lock')}", "ph": "i",
                       "s": "t", "pid": PID_LOCKS,
                       "tid": a.get("lock_id", 0), "ts": _us(ev.t),
                       "args": {"waiter": ev.job,
                                "holder": a.get("holder", "")}})
        elif ev.kind == "lock_timeout":
            te.append({"name": f"timeout:{a.get('lock', 'lock')}", "ph": "i",
                       "s": "t", "pid": PID_LOCKS,
                       "tid": a.get("lock_id", 0), "ts": _us(ev.t),
                       "args": {"waiter": ev.job}})

    for sid in sorted(slots_seen):
        te.append({"ph": "M", "pid": PID_SLOTS, "tid": sid, "ts": 0,
                   "name": "thread_name", "args": {"name": f"slot{sid}"}})
    for gname in groups_seen:
        te.append({"ph": "M", "pid": PID_GROUPS, "tid": groups_seen.index(gname),
                   "ts": 0, "name": "thread_name", "args": {"name": gname}})

    return {"displayTimeUnit": "ms", "traceEvents": te,
            "otherData": {"schema": "repro.core.trace/v1",
                          "n_source_events": len(events)}}


def write_chrome_trace(events: list, path: str,
                       end: Optional[float] = None) -> int:
    """Validate and write a Chrome trace export; returns the number of
    trace_event records written.  Output bytes are deterministic for a
    deterministic event stream (sorted keys, fixed float formatting)."""
    doc = to_chrome_trace(events, end=end)
    n = validate_chrome_trace(doc)
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, separators=(",", ":"))
    return n


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------

def validate_events(events: list, balanced: bool = True) -> dict:
    """Check the event stream against the schema; raises
    :class:`TraceSchemaError` on violation, returns per-kind counts.

    Invariants: known kinds, finite non-negative timestamps, every
    ``start_job`` on a slot closed by a ``stop_job`` before the next start
    on that slot (``balanced=False`` tolerates a trailing open run, e.g. a
    truncated ring), and prefix-balanced boost/unboost per job."""
    counts: Counter = Counter()
    running: dict = {}
    boosted: Counter = Counter()
    for i, ev in enumerate(events):
        if ev.kind not in EVENT_KINDS:
            raise TraceSchemaError(f"event {i}: unknown kind {ev.kind!r}")
        if not math.isfinite(ev.t) or ev.t < 0.0:
            raise TraceSchemaError(f"event {i}: bad timestamp {ev.t!r}")
        counts[ev.kind] += 1
        if ev.kind == "start_job":
            if ev.slot < 0 or ev.jid < 0:
                raise TraceSchemaError(f"event {i}: start_job without slot/jid")
            if ev.slot in running:
                raise TraceSchemaError(
                    f"event {i}: start_job on slot {ev.slot} while "
                    f"{running[ev.slot].job!r} still running")
            running[ev.slot] = ev
        elif ev.kind == "stop_job":
            started = running.pop(ev.slot, None)
            if started is None:
                raise TraceSchemaError(
                    f"event {i}: stop_job on idle slot {ev.slot}")
            if started.jid != ev.jid:
                raise TraceSchemaError(
                    f"event {i}: stop_job jid {ev.jid} != started {started.jid}")
        elif ev.kind == "boost":
            boosted[ev.jid] += 1
        elif ev.kind == "unboost":
            boosted[ev.jid] -= 1
            if boosted[ev.jid] < 0:
                raise TraceSchemaError(
                    f"event {i}: unboost of job {ev.jid} without boost")
    if balanced and running:
        raise TraceSchemaError(
            f"unbalanced trace: slots {sorted(running)} still running at end")
    return dict(counts)


_CHROME_PHASES = {"X", "i", "M"}


def validate_chrome_trace(doc: dict) -> int:
    """Structural validation of a Chrome trace_event export; raises
    :class:`TraceSchemaError`, returns the record count."""
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise TraceSchemaError("export must be an object with 'traceEvents'")
    evs = doc["traceEvents"]
    if not evs:
        raise TraceSchemaError("empty traceEvents")
    for i, ev in enumerate(evs):
        for key in ("name", "ph", "pid", "tid", "ts"):
            if key not in ev:
                raise TraceSchemaError(f"record {i}: missing {key!r}")
        if ev["ph"] not in _CHROME_PHASES:
            raise TraceSchemaError(f"record {i}: unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise TraceSchemaError(f"record {i}: bad ts {ev['ts']!r}")
        if ev["ph"] == "X" and not (isinstance(ev.get("dur"), (int, float))
                                    and ev["dur"] >= 0):
            raise TraceSchemaError(f"record {i}: X event needs dur >= 0")
        if ev["ph"] == "i" and ev.get("s") not in ("t", "p", "g"):
            raise TraceSchemaError(f"record {i}: instant event needs scope")
        if ev["ph"] == "M" and "name" not in (ev.get("args") or {}):
            raise TraceSchemaError(f"record {i}: metadata event needs args.name")
    return len(evs)


# ---------------------------------------------------------------------------
# CLI: produce and validate a sample trace (CI artifact)
# ---------------------------------------------------------------------------

def main(argv: Optional[list] = None) -> None:
    import argparse

    from .experiment import run_mix

    ap = argparse.ArgumentParser(
        description="Run a small mixed workload in simulation and export a "
                    "validated Chrome trace (open at https://ui.perfetto.dev)")
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--policy", default="ufs")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--warmup", type=float, default=0.2)
    args = ap.parse_args(argv)

    tracer = SchedTracer()
    run_mix(args.policy, n_slots=args.slots, n_bursty=args.slots,
            n_bound=args.slots, duration=args.duration, warmup=args.warmup,
            tracer=tracer)
    events = tracer.events
    validate_events(events, balanced=False)
    n = write_chrome_trace(events, args.out,
                           end=args.warmup + args.duration)
    s = tracer.summary()
    print(f"{args.out}: {n} trace records from {s.events} events "
          f"({s.dropped} dropped), kinds={sorted(s.counts)}")


if __name__ == "__main__":
    main()
