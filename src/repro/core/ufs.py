"""UFS -- the selectively unfair scheduler (paper sections 4 and 5).

Two tiers with strict precedence:

* **time-sensitive**: *direct-to-slot enqueue* -- pick a slot that can run the
  job promptly (idle, or running background work -> preemption kick), insert
  into its local DSQ ordered by task vruntime;
* **background**: *group-queue enqueue* -- push onto the job's group DSQ and
  register the group in the runnable tree; idle slots *pull* work on demand
  via the dispatch callback (deferred, reactive load distribution).

Weight-proportional sharing within each tier comes from two-level
weight-scaled virtual runtime (``repro.core.vruntime``); priority-inversion
avoidance from hint-driven boosting (``repro.core.hints``), which temporarily
treats a background lock holder as time-sensitive until it releases the lock.
"""
from __future__ import annotations

from typing import Optional

from . import vruntime as vrt
from .base import Policy, Slot
from .runnable_tree import RunnableTree
from .task import Job, JobState, Tier, WorkloadGroup

MAX_DISPATCH_RETRIES = 8   # bounded loop, eBPF-verifier style (paper 5.1.3)
UFS_SLICE = 0.0015         # bounded execution interval (matches Table 3 latencies)


class UFSPolicy(Policy):
    name = "ufs"

    def __init__(self, slice_s: float = UFS_SLICE):
        self.slice_s = slice_s
        self.tree = RunnableTree()
        self._rr_cursor = 0      # round-robin start for idle-slot scans

    # ------------------------------------------------------------------
    def task_slice(self, job: Job) -> float:
        return self.slice_s

    # ------------------------------------------------------------- enqueue
    def enqueue(self, job: Job, requeue: bool = False) -> None:
        """sched_ext ``enqueue``: state lookup, vruntime clamp, then
        direct-to-slot (TS) or group-queue (BG) insertion (paper 5.1.2)."""
        group = job.group
        if not requeue:
            # Clamp credit hoarding on wakeup only: a requeued (still-active)
            # task keeps its earned position (paper 5.1.2 targets tasks
            # "idle for a long time").
            vrt.clamp_task_vruntime(job, self.slice_s)
        if job.tier == Tier.TIME_SENSITIVE:
            self._enqueue_direct(job)
        else:
            self._enqueue_group(job, group)

    def _enqueue_direct(self, job: Job) -> None:
        kernel = self.kernel
        slot, preempt = self._select_slot(job)
        slot.local_dsq.push(job, job.vruntime)
        job.location = ("local", slot)
        if slot.current is None:
            kernel.kick(slot, preempt=False)            # wake the idle slot
        elif preempt:
            kernel.kick(slot, preempt=True)             # preemption kick
        # else: other TS work is running; vruntime decides queue position.

    def _select_slot(self, job: Job) -> tuple:
        """Direct-to-CPU placement: prefer the previous slot if it can run the
        job promptly, else any idle slot, else any slot running background
        work (kick), else the least TS-loaded slot. Round-robin scan start
        balances placement from the beginning (paper section 4)."""
        kernel = self.kernel
        slots = kernel.online_slots()
        if job.pinned_slot is not None:
            slot = kernel.slots[job.pinned_slot]
            preempt = slot.current is not None and slot.current.tier == Tier.BACKGROUND
            return slot, preempt
        affinity = job.group.slot_affinity
        if affinity is not None:
            allowed = [s for s in slots if s.sid in affinity]
            if allowed:
                slots = allowed
            else:
                # The affinity mask matches no online slot (drained away or
                # misconfigured): fall back to the full online set rather
                # than crash the placement path.
                affinity = None
        # 1. previous slot, if idle or running background work.
        prev = kernel.slots[job.prev_slot] if 0 <= job.prev_slot < len(kernel.slots) else None
        if prev is not None and prev.online and (affinity is None or prev.sid in affinity):
            if prev.current is None and len(prev.local_dsq) == 0:
                return prev, False
            if prev.current is not None and prev.current.tier == Tier.BACKGROUND:
                return prev, True
        # 2. any idle slot (rotating scan start avoids stacking).
        n = len(slots)
        for i in range(n):
            s = slots[(self._rr_cursor + i) % n]
            if s.current is None and len(s.local_dsq) == 0:
                self._rr_cursor = (self._rr_cursor + i + 1) % n
                return s, False
        # 3. any slot running background work -> preempt it.
        for i in range(n):
            s = slots[(self._rr_cursor + i) % n]
            if s.current is not None and s.current.tier == Tier.BACKGROUND:
                self._rr_cursor = (self._rr_cursor + i + 1) % n
                return s, True
        # 4. all slots busy with TS work: least-loaded local DSQ.
        best = min(slots, key=lambda s: (len(s.local_dsq), s.sid))
        return best, False

    def _enqueue_group(self, job: Job, group: WorkloadGroup) -> None:
        group.dsq.push(job, job.vruntime)
        job.location = ("group", group)
        if group not in self.tree:
            # Clamp stale credit only for groups that were *genuinely* idle;
            # a group whose single task just round-tripped through a slice
            # keeps its earned (weight-proportional) position.
            if self.kernel.now - group.last_active > 2 * self.slice_s:
                vrt.clamp_group_vruntime(group, self.tree.min_vruntime(),
                                         self.slice_s)
            self.tree.insert(group)
        # A BG arrival never preempts, but an *idle* slot should pull now.
        for slot in self.kernel.online_slots():
            if slot.idle:
                self.kernel.kick(slot, preempt=False)
                break

    # ------------------------------------------------------------- dispatch
    def dispatch(self, slot: Slot) -> None:
        """Slot's local DSQ is empty -> no time-sensitive work needs it; pull
        the least-served background group's least-served task (paper 5.1.3)."""
        for _ in range(MAX_DISPATCH_RETRIES):
            group = self.tree.peek_min()
            if group is None:
                return
            if len(group.dsq) == 0:
                self.tree.remove(group)      # empty -> stash bookkeeping node
                continue
            if not self._eligible(group, slot):
                # Rate-capped or affinity-excluded group: charge and rotate.
                self.tree.remove(group)
                vrt.charge_group(group, self.slice_s)
                self.tree.insert(group)
                continue
            job = group.dsq.pop_front()
            if job.state != JobState.RUNNABLE:   # vanished (exited/boosted away)
                continue
            job.location = None
            slot.local_dsq.push(job, job.vruntime)
            vrt.charge_group(group, self.slice_s)
            group.last_active = self.kernel.now
            self.tree.remove(group)
            if len(group.dsq) > 0:
                self.tree.insert(group)          # re-key by updated vruntime
            return

    def _eligible(self, group: WorkloadGroup, slot: Slot) -> bool:
        if group.slot_affinity is not None and slot.sid not in group.slot_affinity:
            return False
        if group.rate_cap is not None:
            elapsed = max(self.kernel.now, 1e-9)
            capacity = elapsed * len(self.kernel.online_slots())
            if group.usage_time >= group.rate_cap * capacity:
                return False
        return True

    # ------------------------------------------------------------- charging
    def stopping(self, job: Job, slot: Slot, used: float) -> None:
        vdelta = vrt.charge_task(job, used)
        job.last_ran = self.kernel.now
        group = job.sched_group()
        if job.vruntime > group.task_vmax:
            # Task-level watermark: the clamp reference for re-entering
            # tasks. Weight-scaled task vruntimes are directly comparable
            # across groups, which yields weight-proportional sharing within
            # the TS tier (Figure 8) without tree dispatch.
            group.task_vmax = job.vruntime
        if group.tier == Tier.TIME_SENSITIVE:
            group.vruntime += vdelta              # service accounting/metrics

    # ------------------------------------------------------------- boosting
    def on_boost(self, job: Job) -> None:
        """A background lock holder was boosted into the TS tier: enter at
        the inherited group's current vruntime level (no stale credit, no
        stale debt from the background scale) and, if queued in its group
        DSQ, move to the direct-to-slot path immediately."""
        if job.boost_group is not None:
            job.vruntime = job.boost_group.task_vmax
        if job.state != JobState.RUNNABLE or job.location is None:
            return
        kind, ref = job.location
        if kind == "group":
            ref.dsq.remove(job)
            job.location = None
            self._enqueue_direct(job)
        # if already on a local DSQ or running, tier change suffices.

    def on_unboost(self, job: Job) -> None:
        """Boost expired (lock released): demote a queued job back to the
        background path so it does not keep borrowed priority."""
        job.vruntime = job.group.task_vmax     # re-baseline on the BG scale
        if job.state != JobState.RUNNABLE or job.location is None:
            return
        kind, ref = job.location
        if kind == "local":
            ref.local_dsq.remove(job)
            job.location = None
            self._enqueue_group(job, job.group)
