"""Two-level weight-scaled virtual runtime accounting (paper section 5.1.1).

UFS tracks virtual runtime at two levels:

1. *task vruntime* -- runtime of a task within its group, scaled by the
   group's effective weight (weight-scaled virtual runtime);
2. *group vruntime* -- service received by the group as a whole, advanced by
   one slice scaled inversely by effective weight each time the group is
   charged at dispatch.

Clamping (section 5.1.2) limits how far behind the group's current vruntime a
task may lag, preventing long-idle tasks from hoarding credit and starving
recently-active peers on re-entry.
"""
from __future__ import annotations

from .task import Job, WorkloadGroup

# Weight normalisation: vruntime advances as wall/(eff_weight/SCALE), so a
# weight-100 (cgroup default) task's vruntime tracks wall time 1:1.
WEIGHT_SCALE = 100.0


def weight_scaled_delta(wall_delta: float, group: WorkloadGroup) -> float:
    """Convert wall-clock service into weight-scaled virtual runtime."""
    eff = max(group.effective_weight(), 1e-9)
    return wall_delta * (WEIGHT_SCALE / eff)


def charge_task(job: Job, wall_delta: float) -> float:
    """Charge ``wall_delta`` seconds of service to a task; returns the vdelta.
    A boosted job charges at its inherited (time-sensitive) group's weight --
    priority inheritance, so the boost is actually effective."""
    vdelta = weight_scaled_delta(wall_delta, job.sched_group())
    job.vruntime += vdelta
    job.total_cpu += wall_delta
    job.group.usage_time += wall_delta
    return vdelta


def charge_group(group: WorkloadGroup, slice_s: float) -> float:
    """Advance group vruntime by one slice scaled inversely by effective
    weight (paper: 'Its virtual runtime is then advanced by one time slice,
    scaled inversely by the cgroup's effective weight')."""
    vdelta = weight_scaled_delta(slice_s, group)
    group.vruntime += vdelta
    return vdelta


def clamp_task_vruntime(job: Job, slice_s: float) -> None:
    """Clamp a task's vruntime to at most one task slice behind its group's
    current task-level vruntime watermark (paper section 5.1.2, 'Clamping
    virtual runtime'): a long-idle task re-enters just behind the group's
    recently-active tasks instead of hoarding credit."""
    group = job.sched_group()
    floor = group.task_vmax - weight_scaled_delta(slice_s, group)
    if job.vruntime < floor:
        job.vruntime = floor


def clamp_group_vruntime(group: WorkloadGroup, min_tree_vruntime: float, slice_s: float) -> None:
    """When a group re-enters the runnable tree after being empty, clamp its
    vruntime near the current tree minimum so it cannot monopolise slots with
    stale credit (mirrors the task-level clamp one level up)."""
    floor = min_tree_vruntime - weight_scaled_delta(slice_s, group)
    if group.vruntime < floor:
        group.vruntime = floor
