"""Sim-mode workload generators modelling the paper's mixed DB workloads.

Calibrated against the paper's own measurements (Table 3 SOLO: mean 3.06 ms,
p95 5.80 ms for TPC-C on dedicated cores):

* :func:`bursty_worker`    -- CPU-bursty interactive transactions (TPC-C
  analogue; in the TPU adaptation: interactive decode / short queries).
  Closed loop: think -> request -> single Gamma(k=3) CPU burst -> reply.
* :func:`bound_worker`     -- CPU-bound analytics (TPC-H Q17-in-a-UDF
  analogue; TPU: training / bulk prefill). Long bursts with rare, very short
  I/O waits; completing ``query_cpu`` seconds of CPU finishes one query.
* :func:`schbench_worker`  -- the schbench-style wakeup-latency workload.
* :func:`holder` / :func:`waiter` / :func:`burner` -- the Table 4
  priority-inversion micro-experiment.
"""
from __future__ import annotations

import random
from typing import Iterator, Optional

from .locks import spin_acquire
from .task import (Block, Burst, Exit, ReleaseLock, RequestBegin, RequestEnd)

# Calibration against Table 3 SOLO (mean 3.06 ms, p95 5.80 ms): a TPC-C
# transaction is ~2 ms of CPU in two bursts around ~1 ms of in-server
# non-CPU time (WAL flush, buffer I/O, row-lock waits), with a short client
# round-trip between transactions. CPU demand per worker ~= 60%.
BURST_CPU_MEAN = 2.0e-3      # total CPU per transaction (Gamma, shape 2)
TX_IO = 1.0e-3               # in-server non-CPU time per transaction
THINK_TIME = 0.3e-3          # client round-trip + client-side processing
QUERY_CPU = 1.0              # CPU seconds per analytics query


def bursty_worker(seed: int, think: float = THINK_TIME,
                  cpu_mean: float = BURST_CPU_MEAN,
                  tx_io: float = TX_IO) -> Iterator:
    """Closed-loop interactive worker (one backend serving one client)."""
    rng = random.Random(seed)
    while True:
        yield Block(think)
        yield RequestBegin()
        yield Burst(rng.gammavariate(1, cpu_mean / 2))
        yield Block(tx_io)
        yield Burst(rng.gammavariate(1, cpu_mean / 2))
        yield RequestEnd()


def bound_worker(seed: int, query_cpu: float = QUERY_CPU,
                 io: float = 0.0) -> Iterator:
    """CPU-bound analytics loop (UDF running TPC-H Q17 continuously over hot
    buffers: pure CPU, never voluntarily sleeps; ``io`` > 0 adds per-query
    I/O waits for colder working sets)."""
    rng = random.Random(seed)
    while True:
        yield RequestBegin()
        yield Burst(query_cpu * rng.uniform(0.95, 1.05))
        yield RequestEnd()
        if io > 0:
            yield Block(io)


def schbench_worker(seed: int, think: float = 100e-6, compute: float = 30e-6,
                    n_ops: int = 5) -> Iterator:
    """schbench analogue: frequent sleep/wakeup with short compute phases
    (-n 5 operations per compute phase, moderate cache-pressure settings)."""
    rng = random.Random(seed)
    while True:
        yield Block(rng.expovariate(1.0 / think))
        yield RequestBegin()
        for _ in range(n_ops):
            yield Burst(rng.expovariate(1.0 / compute))
        yield RequestEnd()


# ---------------------------------------------------------------------------
# Table 4 priority-inversion micro-experiment
# ---------------------------------------------------------------------------

def holder(lock, compute: float = 3.0) -> Iterator:
    """Background task: acquire the spinlock, compute (1e9 simple ops ~= 3 s),
    release (paper section 6.6)."""
    yield RequestBegin()
    yield from spin_acquire(lock)
    yield Burst(compute)
    yield ReleaseLock(lock)
    yield RequestEnd()
    yield Exit()


def waiter(lock, start_delay: float = 0.1, compute: float = 0.05) -> Iterator:
    """Time-sensitive task: wants the same spinlock immediately after."""
    yield Block(start_delay)
    yield RequestBegin()
    yield from spin_acquire(lock)
    yield Burst(compute)
    yield ReleaseLock(lock)
    yield RequestEnd()
    yield Exit()


def burner(start_delay: float = 0.2, chunk: float = 10.0,
           total: Optional[float] = None) -> Iterator:
    """Time-sensitive task: synthetic CPU-bound tight loop pinned with the
    others; starves the holder unless the scheduler intervenes."""
    yield Block(start_delay)
    done = 0.0
    while total is None or done < total:
        yield Burst(chunk)
        done += chunk
