"""Token data pipeline: deterministic synthetic streams and memory-mapped
binary token files, sharded by data-parallel rank, with background prefetch.

Determinism contract: ``(seed, step, dp_rank)`` fully determines a batch, so
a restarted (or re-scaled) job resumes mid-stream without data skew -- the
fault-tolerance story depends on this (DESIGN.md section 6).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticTokens:
    """Zipf-ish synthetic token stream (shape-true stand-in for a tokenized
    corpus; e.g. the train_100m example)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def batch(self, step: int, dp_rank: int, batch: int, seq: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, dp_rank]))
        # Zipf tail clipped into the vocab; cheap and distribution-plausible.
        toks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
        toks = (toks - 1) % self.vocab
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class TokenFile:
    """Memory-mapped flat int32 token file, chunked into sequences and
    sharded deterministically across data-parallel ranks."""

    def __init__(self, path: str, seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seed = seed

    def batch(self, step: int, dp_rank: int, dp_size: int, batch: int, seq: int):
        n_chunks = (len(self.tokens) - 1) // seq
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        order = rng.permutation(n_chunks)
        base = (step * dp_size + dp_rank) * batch
        idx = order[(base + np.arange(batch)) % n_chunks]
        rows = np.stack([self.tokens[i * seq:(i + 1) * seq + 1] for i in idx])
        return {"tokens": rows[:, :-1].copy(), "labels": rows[:, 1:].copy()}


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def batches(source, *, steps: int, dp_rank: int = 0, dp_size: int = 1,
            batch: int = 8, seq: int = 128, prefetch: int = 2):
    def gen():
        for step in range(steps):
            if isinstance(source, TokenFile):
                yield source.batch(step, dp_rank, dp_size, batch, seq)
            else:
                yield source.batch(step, dp_rank, batch, seq)
    return Prefetcher(gen(), depth=prefetch)
