"""Pipeline parallelism over the pod axis (GPipe-style, shard_map +
collective_permute).

For multi-pod meshes the default maps the ``pod`` axis to data parallelism;
this module provides the alternative: layer stages sharded across pods,
microbatches streamed through a ppermute ring. Forward-only building block
plus a loss wrapper -- used by the dry-run's PP variant and the distributed
tests; the trainer composes it with grad accumulation.

Schedule: classic GPipe fill-drain over ``n_micro`` microbatches with
``n_stages`` stages; bubble fraction (n_stages-1)/(n_micro+n_stages-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, n_stages: int, axis: str):
    """Returns f(stage_params, x_micro) running the fill-drain schedule.

    stage_params: pytree with leading stage axis, sharded over ``axis``;
    x_micro: (n_micro, mb, ...) microbatched activations (replicated).
    stage_fn(params_for_stage, x) -> y, applied at every stage.
    """

    def run(stage_params, x_micro):
        n_micro = x_micro.shape[0]
        stage = jax.lax.axis_index(axis)
        my_params = jax.tree.map(lambda a: a[0], stage_params)  # shard local
        total = n_micro + n_stages - 1
        mb_shape = x_micro.shape[1:]
        carry = jnp.zeros(mb_shape, x_micro.dtype)
        outs = jnp.zeros((n_micro,) + mb_shape, x_micro.dtype)

        def step(t, state):
            carry, outs = state
            # stage 0 injects microbatch t; others use the permuted carry
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage == 0, x_micro[inject], carry)
            y = stage_fn(my_params, x_in)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            emit = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # rotate activations to the next stage
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return carry, outs

        carry, outs = jax.lax.fori_loop(0, total, step, (carry, outs))
        # only the last stage holds real outputs; replicate across stages
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run


def make_pipelined_fn(stage_fn, mesh, n_stages: int, axis: str = "pod",
                      param_specs=None):
    """shard_map wrapper: stage_params sharded on the stage axis, data
    replicated across it (the data/model axes inside stage_fn still apply)."""
    run = pipeline_forward(stage_fn, n_stages, axis)
    in_specs = (param_specs if param_specs is not None else P(axis), P())
    return shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)
