"""Sharding rules: FSDP + TP (+ EP) PartitionSpecs for every pytree in the
system (params, optimizer state, batches, KV caches).

Strategy (per DESIGN.md section 6):

* **data axis (+pod)**: batch dimension of activations; FSDP shard of every
  weight's non-TP dimension (ZeRO-3-style);
* **model axis**: tensor parallelism on head/FF/vocab dims; expert-TP on the
  per-expert FF dim by default, or true EP (expert axis) when selected;
* dims that do not divide evenly fall back to replication (recorded by the
  dry-run; padding heads is a perf-pass lever -- see EXPERIMENTS.md).

Rules are name-driven over tree paths, so they apply uniformly to single
and scan-stacked (leading layer-dim) parameters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import dp_axes


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _pick(dim: int, mesh, axis: Optional[str]):
    """axis name if the dim divides evenly, else None (replicate)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= _axis_size(mesh, a)
        return axis if _fits(dim, total) else None
    return axis if _fits(dim, _axis_size(mesh, axis)) else None


# (tp_dim, fsdp_dim) conventions per parameter name; dims counted from the
# END of the shape so scan-stacked leading layer dims are transparent.
# tp on the output dim for column-parallel, input dim for row-parallel.
_RULES = {
    # attention & generic projections: (d_in, d_out)
    "wq": (-1, -2), "wk": (-1, -2), "wv": (-1, -2),
    "wo": (-2, -1), "wout": (-1, -2),
    "gate": (-1, -2), "up": (-1, -2), "down": (-2, -1),
    "wz": (-1, -2), "wi": (-1, -2), "wf": (-1, -2), "proj": (-2, -1),
    "wb": (-1, -2), "wc": (-1, -2), "wdt": (-1, -2),
    # MLA
    "wq_a": (-1, -2), "wq_b": (-1, -2), "wkv_a": (-1, -2), "wkv_b": (-1, -2),
    # router
    "router": (-1, -2),
}


def param_spec(path, leaf, mesh, ep: bool = False) -> P:
    """PartitionSpec for one parameter leaf."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = None
    for k in reversed(keys):
        if isinstance(k, str) and k not in ("w", "b", "g", "table"):
            name = k
            break
    last = keys[-1]
    ndim = leaf.ndim
    tp = "model"
    # FSDP extends across the pod axis on multi-pod meshes (512-way shards:
    # what makes deepseek-v3 training state fit v5e HBM).
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else "data"

    def build(tp_dim=None, fsdp_dim=None):
        spec = [None] * ndim
        if tp_dim is not None:
            ax = _pick(leaf.shape[tp_dim], mesh, tp)
            if ax is not None:
                spec[tp_dim % ndim] = ax
        if fsdp_dim is not None and spec[fsdp_dim % ndim] is None:
            ax = _pick(leaf.shape[fsdp_dim], mesh, fsdp)
            if ax is not None:
                spec[fsdp_dim % ndim] = ax
        return P(*spec)

    if last == "table":
        # embedding (vocab, d): feature-dim TP, vocab replicated -- a
        # vocab-sharded table turns the token gather into an SPMD
        # full-rematerialization (XLA replicates the table per step).
        return build(-1, None)
    if last == "b":                          # bias (out,)
        return build(-1, None)
    if last == "g":                          # norm scale
        return P(*([None] * ndim))
    if name == "experts" or (ndim >= 3 and name in ("gate", "up", "down")
                             and last in ("gate", "up", "down")):
        # expert weights (E, d, f) / (E, f, d)
        if ep:
            spec = [None] * ndim
            e_dim = ndim - 3
            ax = _pick(leaf.shape[e_dim], mesh, tp)
            if ax is not None:
                spec[e_dim] = ax
            # FSDP on d_model dim
            d_dim = ndim - 2 if last in ("gate", "up") else ndim - 1
            ax = _pick(leaf.shape[d_dim], mesh, fsdp)
            if ax is not None:
                spec[d_dim] = ax
            return P(*spec)
        # expert-TP: shard the per-expert FF dim
        ff_dim = -1 if last in ("gate", "up") else -2
        d_dim = -2 if last in ("gate", "up") else -1
        return build(ff_dim, d_dim)
    if name in _RULES or last in _RULES:
        tp_dim, fsdp_dim = _RULES.get(last, _RULES.get(name))
        return build(tp_dim, fsdp_dim)
    if ndim >= 2:
        return build(-1, -2)
    return P(*([None] * ndim))


def params_shardings(params, mesh, ep: bool = False):
    """NamedSharding tree for a parameter (or optimizer-state) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh, ep=ep)),
        params)


def batch_shardings(batch, mesh):
    """Batch dict: leading dim over (pod+)data axes."""
    dp = dp_axes(mesh)

    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        ax = _pick(b, mesh, dp)
        return NamedSharding(mesh, P(*((ax,) + (None,) * (leaf.ndim - 1))))
    return jax.tree.map(spec, batch)


def cache_shardings(caches, mesh):
    """KV caches / recurrent state: batch dim over data axes when it
    divides; otherwise the longest other dim (sequence, for long-context
    batch-1 decode) over data. Head-count dims over model when divisible."""
    dp = dp_axes(mesh)

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        dims = list(leaf.shape)
        spec_list = [None] * leaf.ndim
        # batch dim: first dim of size>1 that divides dp; scan-stacked caches
        # carry a leading layer dim -- detect via heuristic: try dim 0 then 1.
        placed_dp = False
        for d in range(min(2, leaf.ndim)):
            if _pick(dims[d], mesh, dp) is not None and dims[d] >= 2:
                spec_list[d] = _pick(dims[d], mesh, dp)
                placed_dp = True
                break
        if not placed_dp:
            # shard the longest dim (sequence) over data
            longest = max(range(leaf.ndim), key=lambda d: dims[d])
            ax = _pick(dims[longest], mesh, dp)
            if ax is not None and dims[longest] >= 1024:
                spec_list[longest] = ax
        # heads/hidden over model: last-but-one or last dim
        for d in range(leaf.ndim - 1, max(leaf.ndim - 3, 0) - 1, -1):
            if spec_list[d] is None and _fits(dims[d], _axis_size(mesh, "model")) \
                    and dims[d] >= _axis_size(mesh, "model") and d >= 2:
                spec_list[d] = "model"
                break
        return NamedSharding(mesh, P(*spec_list))
    return jax.tree.map(spec, caches)


def replicated(mesh):
    return NamedSharding(mesh, P())
