"""Explicit shard_map GQA attention (EXPERIMENTS.md section Perf, B5).

The GSPMD-auto lowering reshards activations around the flash path's
(B,S,H,hd) <-> (B*H,S,hd) reshapes (iteration B1/B4 diagnosis). This module
expresses the intended schedule explicitly: each model shard

  1. projects q/k/v for *its* heads only (KV weights are pre-expanded to
     per-q-head layout, so grouped heads stay shard-local; the duplicated
     KV projection costs ~ one extra q-projection, negligible vs attention),
  2. runs flash attention locally (the Pallas kernel on TPU),
  3. applies its slice of the output projection and psums across the model
     axis -- the only collective in the mixer.

Restrictions (checked): n_heads divisible by the model-axis size, no QKV
bias. Used by the dry-run variant ``shardmap_attn`` and available to the
trainer via ``Model.shardmap_attn(mesh)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..models import layers as L


def expand_kv_weight(w, kh: int, g: int):
    """(d, KH*hd) -> (d, KH*G*hd): repeat each kv head's columns G times so
    every q head owns a local copy of its kv projection."""
    d, _ = w.shape
    hd = w.shape[1] // kh
    w = w.reshape(d, kh, 1, hd)
    w = jnp.broadcast_to(w, (d, kh, g, hd))
    return w.reshape(d, kh * g * hd)


def make_shardmap_gqa(mesh, cfg, *, backend=None):
    """Returns fwd(p, x, positions, window) -> y for full-sequence GQA."""
    from ..kernels import ops

    tp = mesh.shape["model"]
    if cfg.n_heads % tp:
        raise ValueError(f"n_heads {cfg.n_heads} must divide model axis {tp}")
    if cfg.qkv_bias:
        raise ValueError("shard_map GQA variant does not support qkv bias")
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    h = cfg.n_heads
    kh = cfg.n_kv_heads
    g = h // kh
    hd = cfg.hd

    _cache: dict = {}

    def _smapped(window: int):
        if window in _cache:
            return _cache[window]

        def block(wq, wk, wv, wo, xl, pos):
            b, s, _ = xl.shape
            h_l = wq.shape[1] // hd
            q = (xl @ wq).reshape(b, s, h_l, hd)
            k = (xl @ wk).reshape(b, s, h_l, hd)
            v = (xl @ wv).reshape(b, s, h_l, hd)
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
            qf = q.transpose(0, 2, 1, 3).reshape(b * h_l, s, hd)
            kf = k.transpose(0, 2, 1, 3).reshape(b * h_l, s, hd)
            vf = v.transpose(0, 2, 1, 3).reshape(b * h_l, s, hd)
            of = ops.flash_attention(qf, kf, vf, causal=True, window=window,
                                     backend=backend)
            out = of.reshape(b, h_l, s, hd).transpose(0, 2, 1, 3) \
                .reshape(b, s, h_l * hd)
            partial = out @ wo                  # (b, s, d) partial sum
            return jax.lax.psum(partial, "model")

        _cache[window] = shard_map(
            block, mesh=mesh,
            in_specs=(P(None, "model"), P(None, "model"), P(None, "model"),
                      P("model", None), P(dp_spec, None, None),
                      P(dp_spec, None)),
            out_specs=P(dp_spec, None, None), check_rep=False)
        return _cache[window]

    def fwd(p, x, positions, window: int = 0):
        wk = expand_kv_weight(p["wk"]["w"].astype(x.dtype), kh, g)
        wv = expand_kv_weight(p["wv"]["w"].astype(x.dtype), kh, g)
        positions = jnp.broadcast_to(positions, x.shape[:2]).astype(jnp.int32)
        return _smapped(window)(p["wq"]["w"].astype(x.dtype), wk, wv,
                                p["wo"]["w"].astype(x.dtype), x, positions)

    return fwd
