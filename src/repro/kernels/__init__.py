"""Pallas TPU kernels for the perf-critical compute hot spots, each with a
jit'd wrapper (ops.py) and a pure-jnp oracle (ref.py):

* flash_attention  -- train/prefill attention, O(seq) memory
* decode_attention -- single-token attention over long KV caches (serving)
* mlstm_scan       -- chunkwise-parallel mLSTM / SSD linear attention
* moe_topk         -- fused MoE router (softmax + top-k + renormalize)
"""
from . import ops, ref

__all__ = ["ops", "ref"]
