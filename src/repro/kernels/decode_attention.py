"""Single-token decode attention over a long KV cache -- Pallas TPU kernel.

The serving hot spot: one query token attends to a KV cache of up to 512k
positions. Memory-bound by design (every cache byte is read once), so the
kernel streams KV blocks HBM->VMEM and keeps the online-softmax running
state in VMEM scratch. Grid (batch*q_heads, kv_blocks), kv innermost.

A `length` operand masks positions beyond the live cache length (paged /
ragged caches pass their fill level).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale: float, bk: int, nk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_start = ik * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (1, d)
        k = k_ref[0].astype(jnp.float32)                   # (bk, d)
        v = v_ref[0].astype(jnp.float32)                   # (bk, d)
        s = (q @ k.T)[0]                                   # (bk,)
        kpos = k_start + jax.lax.iota(jnp.int32, bk)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(kpos < length, jnp.exp(s - m_new), 0.0)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * alpha + (p[None, :] @ v)
        m_ref[0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, scale: float | None = None,
                            block_k: int = 1024, interpret: bool = False):
    """q: (BH, 1, D); k, v: (BH, S, D); lengths: (BH,) int32 live lengths."""
    bh, one, d = q.shape
    assert one == 1
    sk = k.shape[1]
    bk = min(block_k, sk)
    assert sk % bk == 0
    nk = sk // bk
    scale = scale if scale is not None else d ** -0.5

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
