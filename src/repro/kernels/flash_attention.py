"""Flash attention forward -- Pallas TPU kernel.

Online-softmax attention with O(seq) memory: grid (batch*heads, q_blocks,
kv_blocks), kv innermost so the VMEM scratch (acc, running max m, running
sum l) carries across kv steps for one q block. Causal and sliding-window
masking are predicated per block; fully-masked blocks are skipped with
``pl.when`` (no MXU work issued).

BlockSpec tiling targets TPU v5e: block sizes are multiples of 128 on both
the q and kv axes (MXU/lane alignment), fp32 scratch, bf16-friendly inputs.
VMEM working set per program ~= (bq + 2*bk) * head_dim * 2B + bq*bk*4B
(about 1.3 MB at bq=bk=512, hd=128), comfortably under the ~16 MB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int, offs: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # When sq != sk the q tokens are the LAST sq positions of the kv space
    # (decode-continuation convention, same as ops._flash_xla / ref).
    q_start = iq * bq + offs
    k_start = ik * bk

    # Block-level reachability: skip fully-masked kv blocks.
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window > 0:
        # kv block must overlap [q_pos - window + 1, q_pos] for some q in block
        reachable = jnp.logical_and(reachable,
                                    k_start + bk - 1 >= q_start - window + 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = q @ k.T                                       # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q: (BH, Sq, D); k, v: (BH, Sk, D). Returns (BH, Sq, D).

    Head grouping (GQA) is resolved by the caller (ops.py) by expanding /
    reindexing KV heads into the BH axis.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = scale if scale is not None else d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, offs=(sk - sq if causal else 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
