"""Chunkwise-parallel mLSTM (matrix-memory linear attention) -- Pallas TPU.

The xLSTM/hymba recurrence
    C_t = f_t * C_{t-1} + i_t * k_t v_t^T        (matrix memory, D x D)
    n_t = f_t * n_{t-1} + i_t * k_t              (normalizer)
    h_t = (q_t C_t) / max(|q_t . n_t|, 1)
is evaluated chunk-parallel: within a chunk of length L the contribution is
a masked, decay-weighted attention matrix (intra), plus the carried state
applied with cumulative decay (inter). The grid is (batch*heads, chunks)
with chunks innermost-sequential; C and n live in VMEM scratch across chunk
steps -- the TPU-native replacement for a per-timestep recurrence, giving
MXU-shaped (L x D) matmuls instead of D-wide vector ops.

Gates use log-sigmoid decay accumulated in log space for stability
(sigmoid-gated linear-attention form; see DESIGN.md section 8 for the
deviation from the exp-gate + stabilizer formulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, logf_ref, i_ref, o_ref, c_ref, n_ref,
                  *, chunk: int, scale: float):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0].astype(jnp.float32) * scale      # (L, d)
    k = k_ref[0].astype(jnp.float32)              # (L, d)
    v = v_ref[0].astype(jnp.float32)              # (L, d)
    logf = logf_ref[0].astype(jnp.float32)        # (L,)
    ig = i_ref[0].astype(jnp.float32)             # (L,)

    la = jnp.cumsum(logf)                         # cumulative log-decay
    total = la[-1]
    decay_in = jnp.exp(la)                        # state-decay seen by step t

    # inter-chunk: carried state applied with per-step decay
    c_prev = c_ref[...]
    n_prev = n_ref[...]
    inter = (q * decay_in[:, None]) @ c_prev                      # (L, d)
    n_inter = (q * decay_in[:, None]) @ n_prev[:, None]           # (L, 1)

    # intra-chunk: pairwise decay D_ij = exp(la_i - la_j) * i_j, j <= t
    li = la[:, None] - la[None, :]                                # (L, L)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dmat = jnp.where(jpos <= tpos, jnp.exp(li) * ig[None, :], 0.0)
    s = (q @ k.T) * dmat                                          # (L, L)
    intra = s @ v                                                 # (L, d)

    num = inter + intra
    # normalizer: |q . n_t| with n_t = decayed carry + intra-chunk sum
    den = jnp.abs(n_inter[:, 0] + jnp.sum(s, axis=-1))
    o_ref[0] = (num / jnp.maximum(den, 1.0)[:, None]).astype(o_ref.dtype)

    # carry updates
    w = ig * jnp.exp(total - la)                                  # (L,)
    c_ref[...] = jnp.exp(total) * c_ref[...] + (k * w[:, None]).T @ v
    n_ref[...] = jnp.exp(total) * n_ref[...] + w @ k


def mlstm_scan_pallas(q, k, v, logf, i, *, chunk: int = 256,
                      scale: float | None = None, interpret: bool = False):
    """q, k: (BH, S, Dk); v: (BH, S, Dv); logf, i: (BH, S).

    Returns h: (BH, S, Dv). Dk == Dv for xLSTM's mLSTM; Dk = ssm_state for
    mamba-2/SSD-style heads (hymba), where k/q are the B/C projections.
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    ch = min(chunk, s)
    assert s % ch == 0
    nc = s // ch
    scale = scale if scale is not None else dk ** -0.5
    kernel = functools.partial(_mlstm_kernel, chunk=ch, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, ch, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch), lambda b, c: (b, c)),
            pl.BlockSpec((1, ch), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, ch, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, logf, i)
