"""Fused MoE router: softmax + top-k + renormalize -- Pallas TPU kernel.

The routing hot spot at the front of every MoE layer: for each token,
softmax over expert logits, select the top-k experts, renormalize the
selected probabilities. Fused in one VMEM pass over a token block (the XLA
decomposition materializes the full softmax plus two sorts in HBM).

Iterative masked-argmax (k <= 8 passes) instead of a sort: O(k*E) VPU work,
no cross-lane sort network.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _router_kernel(logits_ref, w_ref, idx_ref, *, top_k: int, n_valid: int):
    logits = logits_ref[...].astype(jnp.float32)          # (bt, E)
    bt, e = logits.shape
    eidx = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1)
    logits = jnp.where(eidx < n_valid, logits, NEG_INF)   # mask padding experts
    probs = jax.nn.softmax(logits, axis=-1)

    masked = probs
    total = jnp.zeros((bt,), jnp.float32)
    for j in range(top_k):                                # bounded unrolled loop
        best = jnp.argmax(masked, axis=-1)                # (bt,)
        bestp = jnp.max(masked, axis=-1)
        idx_ref[:, j] = best.astype(jnp.int32)
        w_ref[:, j] = bestp
        total = total + bestp
        masked = jnp.where(eidx == best[:, None], NEG_INF, masked)
    w_ref[...] = (w_ref[...] / jnp.maximum(total, 1e-9)[:, None]).astype(w_ref.dtype)


def moe_topk_pallas(logits, top_k: int, n_valid: int | None = None,
                    block_t: int = 1024, interpret: bool = False):
    """logits: (T, E) -> (weights (T, k) f32, indices (T, k) i32).

    ``n_valid`` masks padded experts (EP divisibility padding) out of the
    softmax and the selection.
    """
    t, e = logits.shape
    bt = min(block_t, t)
    assert t % bt == 0
    n_valid = n_valid if n_valid is not None else e
    kernel = functools.partial(_router_kernel, top_k=top_k, n_valid=n_valid)
    return pl.pallas_call(
        kernel,
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, top_k), lambda i: (i, 0)),
            pl.BlockSpec((bt, top_k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, top_k), jnp.float32),
            jax.ShapeDtypeStruct((t, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
