"""jit'd kernel wrappers with backend selection.

Backends:
* ``pallas``    -- the TPU kernels (production target);
* ``interpret`` -- the same Pallas kernel bodies executed in Python on CPU
                   (correctness validation in this container);
* ``xla``       -- pure-jnp *blocked* implementations with the same memory
                   behaviour (online softmax over KV blocks, chunkwise mLSTM).
                   Differentiable, so the training path uses it; the CPU
                   dry-run lowers through it, keeping the roofline honest
                   (no materialized S x S attention at 32k).

``default_backend()`` picks pallas on TPU and xla elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .mlstm_scan import mlstm_scan_pallas
from .moe_topk import moe_topk_pallas

NEG_INF = -1e30


def default_backend() -> str:
    return "pallas" if jax.devices()[0].platform == "tpu" else "xla"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _flash_xla(q, k, v, *, causal, window, scale, block_q=512, block_k=512):
    """Blocked online-softmax attention in pure jnp (flash memory behaviour,
    differentiable). q, k: (BH, S, D); v: (BH, Sk, Dv) -- Dv may differ (MLA)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    offs = sk - sq if causal else 0     # query positions offset into kv space
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qblocks = qf.reshape(bh, nq, bq, d).transpose(1, 0, 2, 3)      # (nq,BH,bq,d)
    kblocks = kf.reshape(bh, nk, bk, d).transpose(1, 0, 2, 3)
    vblocks = vf.reshape(bh, nk, bk, dv).transpose(1, 0, 2, 3)

    def q_step(_, qi_blk):
        iq, qb = qi_blk                                            # qb (BH,bq,d)
        qpos = iq * bq + jnp.arange(bq) + offs

        def kv_step(carry, kv):
            m, l, acc = carry
            ik, kb, vb = kv
            kpos = ik * bk + jnp.arange(bk)
            s = jnp.einsum("bqd,bkd->bqk", qb, kb)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None], p, 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bqk,bkd->bqd", p, vb)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((bh, bq), NEG_INF), jnp.zeros((bh, bq)),
                jnp.zeros((bh, bq, dv)))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kblocks, vblocks))
        return None, acc / jnp.maximum(l, 1e-30)[..., None]

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qblocks))
    return out.transpose(1, 0, 2, 3).reshape(bh, sq, dv).astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, backend: str | None = None,
                    block_q: int = 512, block_k: int = 512):
    """Multi-head attention, flash-style. q: (BH, Sq, D); k, v: (BH, Sk, D)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    backend = backend or default_backend()
    if backend == "pallas" or backend == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, block_q=block_q,
                                      block_k=block_k,
                                      interpret=(backend == "interpret"))
    if backend == "xla":
        return _flash_xla(q, k, v, causal=causal, window=window, scale=scale,
                          block_q=block_q, block_k=block_k)
    if backend == "naive":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    raise ValueError(backend)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, lengths, *, scale: float | None = None,
                     backend: str | None = None, block_k: int = 1024):
    """q: (BH, 1, D); k, v: (BH, S, D); lengths: (BH,)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return decode_attention_pallas(q, k, v, lengths, scale=scale,
                                       block_k=block_k,
                                       interpret=(backend == "interpret"))
    return ref.decode_attention_ref(q, k, v, lengths, scale=scale)


# ---------------------------------------------------------------------------
# mLSTM chunkwise scan
# ---------------------------------------------------------------------------

def _mlstm_xla(q, k, v, logf, i, *, scale, chunk=256):
    """Chunkwise-parallel mLSTM in pure jnp (differentiable).
    q, k: (BH, S, Dk); v: (BH, S, Dv)."""
    bh, s, d = q.shape
    dv = v.shape[-1]
    ch = min(chunk, s)
    nc = s // ch
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lf = logf.astype(jnp.float32).reshape(bh, nc, ch)
    ig = i.astype(jnp.float32).reshape(bh, nc, ch)
    qc = qf.reshape(bh, nc, ch, d).transpose(1, 0, 2, 3)
    kc = kf.reshape(bh, nc, ch, d).transpose(1, 0, 2, 3)
    vc = vf.reshape(bh, nc, ch, dv).transpose(1, 0, 2, 3)
    lc = lf.transpose(1, 0, 2)
    ic = ig.transpose(1, 0, 2)
    tpos = jnp.arange(ch)[:, None]
    jpos = jnp.arange(ch)[None, :]

    def chunk_step(carry, xs):
        c, n = carry                                   # (BH,d,d), (BH,d)
        qb, kb, vb, lb, ib = xs
        la = jnp.cumsum(lb, axis=-1)                   # (BH, ch)
        total = la[:, -1]
        decay_in = jnp.exp(la)
        inter = jnp.einsum("btd,bde->bte", qb * decay_in[..., None], c)
        n_inter = jnp.einsum("btd,bd->bt", qb * decay_in[..., None], n)
        dmat = jnp.where(jpos <= tpos,
                         jnp.exp(la[:, :, None] - la[:, None, :]) * ib[:, None, :],
                         0.0)
        smat = jnp.einsum("btd,bjd->btj", qb, kb) * dmat
        intra = jnp.einsum("btj,bjd->btd", smat, vb)
        den = jnp.maximum(jnp.abs(n_inter + jnp.sum(smat, axis=-1)), 1.0)
        h = (inter + intra) / den[..., None]
        w = ib * jnp.exp(total[:, None] - la)
        c_new = jnp.exp(total)[:, None, None] * c + jnp.einsum("btd,bte->bde", kb * w[..., None], vb)
        n_new = jnp.exp(total)[:, None] * n + jnp.einsum("bt,btd->bd", w, kb)
        return (c_new, n_new), h

    init = (jnp.zeros((bh, d, dv), jnp.float32), jnp.zeros((bh, d), jnp.float32))
    (_, _), hs = jax.lax.scan(chunk_step, init, (qc, kc, vc, lc, ic))
    return hs.transpose(1, 0, 2, 3).reshape(bh, s, dv).astype(q.dtype)


def mlstm_scan(q, k, v, logf, i, *, chunk: int = 256,
               scale: float | None = None, backend: str | None = None):
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return mlstm_scan_pallas(q, k, v, logf, i, chunk=chunk, scale=scale,
                                 interpret=(backend == "interpret"))
    if backend == "xla":
        return _mlstm_xla(q, k, v, logf, i, scale=scale, chunk=chunk)
    return ref.mlstm_scan_ref(q, k, v, logf, i, scale=scale)


# ---------------------------------------------------------------------------
# MoE router
# ---------------------------------------------------------------------------

def moe_topk(logits, top_k: int, n_valid: int | None = None,
             backend: str | None = None):
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return moe_topk_pallas(logits, top_k, n_valid=n_valid,
                               interpret=(backend == "interpret"))
    return ref.moe_topk_ref(logits, top_k, n_valid=n_valid)
