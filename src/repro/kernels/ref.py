"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """Dense softmax attention. q: (BH, Sq, D); k, v: (BH, Sk, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq if causal else 0)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale: float | None = None):
    """q: (BH, 1, D); k, v: (BH, S, D); lengths: (BH,)."""
    bh, _, d = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale          # (BH,1,S)
    kpos = jnp.arange(s)[None, None, :]
    scores = jnp.where(kpos < lengths[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mlstm_scan_ref(q, k, v, logf, i, *, scale: float | None = None):
    """Step-by-step mLSTM recurrence (the ground truth the chunkwise kernel
    must match). q, k: (BH, S, Dk); v: (BH, S, Dv); logf, i: (BH, S)."""
    bh, s, d = q.shape
    dv = v.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    f = jnp.exp(logf.astype(jnp.float32))
    ig = i.astype(jnp.float32)

    def step(carry, xs):
        c, n = carry                                  # (BH,D,D), (BH,D)
        qt, kt, vt, ft, it = xs
        c = ft[:, None, None] * c + it[:, None, None] * jnp.einsum("bd,be->bde", kt, vt)
        n = ft[:, None] * n + it[:, None] * kt
        num = jnp.einsum("bd,bde->be", qt, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bd,bd->b", qt, n)), 1.0)
        return (c, n), num / den[:, None]

    xs = (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0),
          jnp.moveaxis(f, 1, 0), jnp.moveaxis(ig, 1, 0))
    init = (jnp.zeros((bh, d, dv), jnp.float32), jnp.zeros((bh, d), jnp.float32))
    _, hs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype)


def moe_topk_ref(logits, top_k: int, n_valid: int | None = None):
    """Softmax -> top-k -> renormalize. logits: (T, E)."""
    t, e = logits.shape
    n_valid = n_valid if n_valid is not None else e
    masked = jnp.where(jnp.arange(e)[None, :] < n_valid,
                       logits.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(masked, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    return topw, topi.astype(jnp.int32)
