import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell against the production mesh; record memory analysis, cost
analysis and the collective schedule for the roofline (EXPERIMENTS.md).

The two lines above MUST stay first: JAX locks the device count on first
initialization, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, all_archs, get_arch
from ..distributed import sharding
from ..launch import specs as S
from ..launch.mesh import make_production_mesh
from ..models.transformer import Model
from ..roofline import analysis as RA
from ..training import optimizer as opt
from ..training import trainer as T

DEFAULT_OUT = "results/dryrun.json"


def _train_cfg(arch_cfg, shape, mesh, unroll: bool) -> T.TrainConfig:
    """Production config uses grad_accum=8 (microbatches bound activation
    memory); the unrolled roofline cells use accum=1 so XLA cost analysis
    sees the whole step (a grad-accum scan body is costed once) -- remat
    keeps the lowering activation-bounded either way."""
    if unroll:
        return T.TrainConfig(grad_accum=1,
                             opt=opt.OptimizerConfig(state_dtype="bfloat16"))
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    per_shard = max(shape.global_batch // dp, 1)
    accum = min(8, per_shard)
    while per_shard % accum:
        accum -= 1
    return T.TrainConfig(grad_accum=accum,
                         opt=opt.OptimizerConfig(state_dtype="bfloat16"))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = True, variant: dict | None = None) -> dict:
    """variant: perf-iteration knobs (EXPERIMENTS.md section Perf):
    * kv_quant: int8 KV cache (+per-token-head scales)
    * act_spec: PartitionSpec tuple for activation constraints at blocks
    * ep: True -> expert-parallel sharding (expert axis over model)
    * compress: error-feedback int8 gradient compression in the train step
    """
    variant = variant or {}
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    act_constraint = None
    if variant.get("act_spec") is not None:
        act_constraint = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*variant["act_spec"]))
    # unroll=True applies scanned layers one by one so XLA cost analysis
    # counts every layer (while bodies are costed once, not x trip-count);
    # used for the single-pod roofline cells. Multi-pod validation cells
    # compile the production scan form.
    model = Model(cfg, unroll=unroll, kv_quant=variant.get("kv_quant", False),
                  act_constraint=act_constraint)
    if variant.get("shardmap_attn"):
        from ..distributed.shardmap_attention import make_shardmap_gqa
        model.shardmap_attn = make_shardmap_gqa(mesh, cfg)
    if variant.get("attn_layout"):
        model.attn_layout_constraint = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                tuple(a for a in ("data", "model") if a in mesh.axis_names),
                None, None))
    if variant.get("kv_local_update"):
        model.kv_update_constraint = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                tuple(a for a in ("pod", "data") if a in mesh.axis_names),
                None,
                "model" if cfg.n_kv_heads % mesh.shape["model"] == 0 else None,
                None))
    t0 = time.time()

    params_shapes = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    n_params = RA.count_params(params_shapes)
    p_shard = sharding.params_shardings(params_shapes, mesh,
                                        ep=variant.get("ep", False))

    kind, inputs = S.input_specs(cfg, shape, model)

    if kind == "train":
        tcfg = _train_cfg(cfg, shape, mesh, unroll)
        if variant.get("compress"):
            tcfg = T.TrainConfig(grad_accum=tcfg.grad_accum,
                                 compress_grads=True, opt=tcfg.opt)
        state_shapes = {
            "params": params_shapes,
            "opt": jax.eval_shape(lambda p: opt.init_state(tcfg.opt, p),
                                  params_shapes),
        }
        state_shard = {
            "params": p_shard,
            "opt": sharding.params_shardings(state_shapes["opt"], mesh),
        }
        if tcfg.compress_grads:
            from ..training import grad_compress
            state_shapes["ef"] = jax.eval_shape(
                grad_compress.init_error_state, params_shapes)
            state_shard["ef"] = sharding.params_shardings(state_shapes["ef"], mesh)
        batch_shard = sharding.batch_shardings(inputs[0], mesh)
        step = T.make_train_step(model, tcfg)
        jitted = jax.jit(step, in_shardings=(state_shard, batch_shard),
                         out_shardings=(state_shard, None))
        lowered = jitted.lower(state_shapes, inputs[0])
    elif kind == "prefill":
        batch_shard = sharding.batch_shardings(inputs[0], mesh)
        cache_spec_tree = model.cache_pspecs(mesh, shape.global_batch, shape.seq_len)
        cache_shard = jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps), cache_spec_tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

        def prefill_step(params, batch):
            return model.prefill(params, batch, shape.seq_len)
        jitted = jax.jit(prefill_step, in_shardings=(p_shard, batch_shard),
                         out_shardings=(None, cache_shard))
        lowered = jitted.lower(params_shapes, inputs[0])
    else:  # decode
        caches, token = inputs
        cache_spec_tree = model.cache_pspecs(mesh, shape.global_batch, shape.seq_len)
        cache_shard = jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps), cache_spec_tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        tok_shard = sharding.batch_shardings({"t": token}, mesh)["t"]

        def serve_step(params, caches, token, pos):
            return model.decode_step(params, caches, token, pos)
        jitted = jax.jit(serve_step,
                         in_shardings=(p_shard, cache_shard, tok_shard, None),
                         out_shardings=(None, cache_shard))
        lowered = jitted.lower(params_shapes, caches, token,
                               S.sds((), jnp.int32))

    t_lower = time.time() - t0
    t0 = time.time()
    # skip expensive LLVM passes: we need the optimized+partitioned HLO for
    # cost/memory/collective analysis, not fast host code.
    compiled = lowered.compile({"xla_backend_optimization_level": 0,
                                "xla_llvm_disable_expensive_passes": True})
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                              getattr(mem, "temp_size_in_bytes", 0)),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    mflops = RA.model_flops(cfg, shape, n_params, n_dev)
    roof = RA.analyze(compiled, mflops)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev, "kind": kind,
        "n_params": n_params, "unrolled": unroll,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "roofline": roof.to_dict(),
        "status": "ok",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="both")
    ap.add_argument("--unroll", choices=["yes", "no"], default=None,
                    help="default: yes for single-pod (roofline), no for multi-pod")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    results = {}
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    if args.all:
        cells = []
        for arch in all_archs():
            cfg = get_arch(arch)
            for sname in SHAPES:
                if sname == "long_500k" and not cfg.is_subquadratic():
                    continue
                cells.append((arch, sname))
        # smallest models first so most cells land early
        cells.sort(key=lambda c: get_arch(c[0]).d_model * get_arch(c[0]).n_layers)
    else:
        cells = [(args.arch, args.shape)]

    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    for arch, sname in cells:
        for mp in pods:
            key = f"{arch}|{sname}|{'2x16x16' if mp else '16x16'}"
            if args.skip_existing and results.get(key, {}).get("status") == "ok":
                print(f"[skip] {key}")
                continue
            print(f"[cell] {key} ...", flush=True)
            t0 = time.time()
            unroll = (not mp) if args.unroll is None else (args.unroll == "yes")
            try:
                res = run_cell(arch, sname, mp, unroll=unroll)
                r = res["roofline"]
                print(f"  ok in {time.time()-t0:.0f}s  "
                      f"compute={r['t_compute']*1e3:.2f}ms "
                      f"memory={r['t_memory']*1e3:.2f}ms "
                      f"coll={r['t_collective']*1e3:.2f}ms "
                      f"bottleneck={r['bottleneck']} "
                      f"useful={r['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:  # noqa: BLE001
                res = {"arch": arch, "shape": sname,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  ERROR {type(e).__name__}: {e}", flush=True)
            results[key] = res
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    print(f"done: {n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
