"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state -- the dry-run must set XLA_FLAGS before the
first device query.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: data (DP + FSDP), model (TP + EP); the pod axis defaults to an
    outer data-parallel dimension (pipeline over pods is available through
    distributed.pipeline_parallel)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (4, 2) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple:
    """Axes that carry data parallelism (pod + data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def tp_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
