"""Mixed-workload serving driver: UFS schedules a live inference engine
(time-sensitive) against background training on the same device slots.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 12 --policy ufs [--background-train]

This is the paper's deployment story end-to-end on real JAX work: decode
steps are CPU-bursty time-sensitive jobs; training microbatches are the
CPU-bound background; application hints guard the cache-slot allocator.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_arch
from ..core import (KernelReport, Tier, build_kernel, percentile,
                    write_chrome_trace)
from ..core.live import LiveJob
from ..models.transformer import Model
from ..serving.engine import InferenceEngine, Request
from ..training import optimizer as opt
from ..training import trainer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--policy", default="ufs")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--background-train", action="store_true")
    ap.add_argument("--slots", type=int, default=1)
    ap.add_argument("--kick-latency", type=float, default=0.0,
                    help="seconds before a kick takes effect (chunk-boundary "
                         "model; supported by both executor backends)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace JSON of the run (open at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--report-out", default=None,
                    help="write the KernelReport JSON to this path")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    kernel = build_kernel("live", policy=args.policy, n_slots=args.slots,
                          kick_latency=args.kick_latency,
                          trace=args.trace_out is not None)
    engine = InferenceEngine(model, params, kernel, max_batch=4, max_len=64)
    kernel.start()
    engine.start()

    if args.background_train:
        tcfg = T.TrainConfig(opt=opt.OptimizerConfig(lr=1e-3))
        tstate = T.init_state(model, tcfg, jax.random.PRNGKey(1))
        tstep = jax.jit(T.make_train_step(model, tcfg))
        bg = kernel.create_group("train", Tier.BACKGROUND, 1.0)
        box = {"state": tstate, "steps": 0}

        def train_chunk(budget):
            toks = np.random.randint(0, cfg.vocab_size, (2, 32), np.int32)
            batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
            box["state"], m = tstep(box["state"], batch)
            jax.tree.leaves(box["state"]["params"])[0].block_until_ready()
            box["steps"] += 1
            return "yield"

        kernel.wake(LiveJob(bg, train_chunk, name="bg-train", kind="bound"))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
        reqs.append(engine.submit(Request(prompt=prompt,
                                          max_new_tokens=args.max_new_tokens)))
        time.sleep(0.05)

    deadline = time.monotonic() + 60
    for r in reqs:
        r.done_event.wait(timeout=max(0.0, deadline - time.monotonic()))
    engine.stop()
    time.sleep(0.1)
    kernel.stop()

    lats = [r.latency for r in reqs if r.latency is not None]
    print(f"completed {len(lats)}/{len(reqs)} requests")
    if lats:
        print(f"latency mean {1e3*sum(lats)/len(lats):.1f} ms  "
              f"p95 {1e3*percentile(lats, 95):.1f} ms")
    if args.background_train:
        print(f"background train steps: {box['steps']}")
    report = KernelReport.from_kernel(kernel)
    print(report.pretty())
    if args.report_out:
        report.write(args.report_out)
        print(f"report written to {args.report_out}")
    if args.trace_out:
        n = write_chrome_trace(kernel.tracer.events, args.trace_out,
                               end=kernel.now)
        print(f"wrote {n} trace records to {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
