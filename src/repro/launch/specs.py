"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell --
weak-type-correct, shardable, zero allocation.

``input_specs(cfg, shape)`` returns (step_kind, example_inputs) where the
inputs are ShapeDtypeStructs matching what the corresponding step function
consumes:

* train   : {"tokens", "labels" [, "frames" | "vision_embeds"]}
* prefill : {"tokens" [, frontend embeddings]}
* decode  : (caches, token, pos) -- caches via jax.eval_shape on init_cache
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models.transformer import Model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = sds((b, s), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = sds((b, cfg.encoder_len, cfg.d_model), cfg.dtype)
    if cfg.vision_tokens:
        batch["vision_embeds"] = sds((b, cfg.vision_tokens, cfg.d_model), cfg.dtype)
    return batch


def cache_specs(model: Model, batch_size: int, smax: int):
    return jax.eval_shape(
        lambda: model.init_cache(batch_size, smax))


def input_specs(cfg: ArchConfig, shape: ShapeConfig, model: Model):
    if shape.kind == "train":
        return "train", (batch_specs(cfg, shape, with_labels=True),)
    if shape.kind == "prefill":
        return "prefill", (batch_specs(cfg, shape, with_labels=False),)
    if shape.kind == "decode":
        caches = cache_specs(model, shape.global_batch, shape.seq_len)
        token = sds((shape.global_batch, 1), jnp.int32)
        return "decode", (caches, token)
    raise ValueError(shape.kind)
