"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production meshes come from ``make_production_mesh``; on this CPU container
use --reduced (1 device). Fault tolerance: periodic async checkpoints with
atomic commit; --resume restores the latest valid checkpoint (also after
a simulated --fail-at crash).

``--scheduled`` runs the loop through the unified scheduling core
(DESIGN.md section 5): microbatch steps become a time-sensitive job on a
``LiveKernel`` slot and each checkpoint write a background-tier job on the
same slot machinery, so saves only use slack and never delay a step --
the same SchedCore/UFS objects the simulator and the serving driver use.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp

from ..configs.base import get_arch
from ..data.pipeline import SyntheticTokens, batches
from ..distributed import sharding
from ..models.transformer import Model
from ..training import optimizer as opt
from ..training import trainer as T
from ..training.checkpoint import CheckpointManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a crash after N steps (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--scheduled", action="store_true",
                    help="run the loop under a LiveKernel: steps are a "
                         "time-sensitive job, checkpoint saves background jobs")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    tcfg = T.TrainConfig(
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
        opt=opt.OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                                total_steps=args.steps))
    state = T.init_state(model, tcfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params:,} devices={jax.device_count()}")

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        # Scheduled mode replaces the ad-hoc save thread with background
        # jobs, so the save itself is the unit of scheduled work.
        mgr = CheckpointManager(args.ckpt_dir, keep_n=3,
                                async_save=not args.scheduled)
        if args.resume:
            got = mgr.restore_latest(state)
            if got[0] is not None:
                start_step, state = got
                print(f"resumed from checkpoint step {start_step}")

    step_fn = jax.jit(T.make_train_step(model, tcfg))
    src = SyntheticTokens(cfg.vocab_size, seed=args.seed)

    def make_batch(step: int) -> dict:
        raw = src.batch(step, 0, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.encoder_layers:
            batch["frames"] = jnp.zeros((args.batch, cfg.encoder_len, cfg.d_model))
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model))
        return batch

    t0 = time.time()
    if args.scheduled:
        state = _run_scheduled(args, state, start_step, step_fn, make_batch,
                               mgr, t0)
    else:
        for step in range(start_step, args.steps):
            state, metrics = step_fn(state, make_batch(step))
            if (step + 1) % args.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                rate = (step + 1 - start_step) * args.batch * args.seq / (time.time() - t0)
                print(f"step {step+1:5d} loss {loss:7.4f} "
                      f"lr {float(metrics['lr']):.2e} tok/s {rate:,.0f}",
                      flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state)
            if args.fail_at is not None and step + 1 >= args.fail_at:
                if mgr:
                    mgr.wait()
                raise SystemExit(f"simulated failure at step {step+1} "
                                 f"(restart with --resume)")
    if mgr:
        mgr.save(args.steps, state)
        mgr.wait()
    print("done")


def _run_scheduled(args, state, start_step, step_fn, make_batch, mgr, t0):
    """Drive the training loop through the unified scheduling core.

    One LiveKernel slot, UFS policy: the step loop is a time-sensitive job
    (one chunk = one microbatch), each checkpoint save a background-tier
    job on the same slot.  Saves therefore run only in the slack between
    steps and are preempted at chunk granularity if steps are queued --
    the paper's mixed-workload story applied to the training driver itself.
    """
    from ..core import KernelReport, Tier, build_kernel
    from ..core.live import LiveJob

    kernel = build_kernel("live", policy="ufs", n_slots=1)
    train_g = kernel.create_group("train", Tier.TIME_SENSITIVE, 10_000.0)
    ckpt_g = kernel.create_group("ckpt", Tier.BACKGROUND, 1.0)
    box = {"state": state, "step": start_step, "failed": False,
           "saves_queued": 0, "saves_done": 0}
    done = threading.Event()

    def save_chunk(step: int, snap) -> str:
        mgr.save(step, snap)
        box["saves_done"] += 1
        return "done"

    def train_chunk(budget: float) -> str:
        step = box["step"]
        if step >= args.steps:
            done.set()
            return "done"
        box["state"], metrics = step_fn(box["state"], make_batch(step))
        box["step"] = step + 1
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            rate = (step + 1 - start_step) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step+1:5d} loss {loss:7.4f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {rate:,.0f}", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            snap = box["state"]
            box["saves_queued"] += 1
            kernel.wake(LiveJob(ckpt_g,
                                lambda budget, s=step + 1, st=snap: save_chunk(s, st),
                                name=f"ckpt-{step+1}", kind="bound"))
        if args.fail_at is not None and step + 1 >= args.fail_at:
            box["failed"] = True
            done.set()
            return "done"
        return "yield"

    kernel.start()
    kernel.wake(LiveJob(train_g, train_chunk, name="train-loop", kind="bound"))
    done.wait()
    # Under UFS a 1-slot kernel gives background saves no slack while steps
    # are queued; drain queued saves (now pure slack) before stopping.
    deadline = time.monotonic() + 30.0
    while box["saves_done"] < box["saves_queued"] and time.monotonic() < deadline:
        time.sleep(0.01)
    kernel.stop()
    print(KernelReport.from_kernel(kernel).pretty())
    if box["failed"]:
        if mgr:
            mgr.wait()
        raise SystemExit(f"simulated failure at step {box['step']} "
                         f"(restart with --resume)")
    return box["state"]


if __name__ == "__main__":
    main()
