from .transformer import Model, Segment, build_plan, make_model

__all__ = ["Model", "Segment", "build_plan", "make_model"]
