"""Attention mixers: GQA (with RoPE / QKV bias / sliding window) and MLA
(DeepSeek multi-head latent attention with the absorbed-latent decode path).

Cache conventions (per layer; stacked along a leading layer axis by the
transformer's scan):

* GQA full attention : {"k": (B, S_max, KH, hd), "v": ...}
* GQA sliding window : ring buffer {"k": (B, W, KH, hd), "v": ...}
* MLA                : {"c": (B, S_max, kv_lora), "kr": (B, S_max, rope_dim)}

Decode positions are a traced scalar ``pos`` (same for the whole batch --
the serving engine aligns batches; ragged serving pads to the max length and
masks via per-request lengths).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import layers as L


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.linear_init(k1, cfg.d_model, cfg.n_heads * hd, cfg.dtype, bias=cfg.qkv_bias),
        "wk": L.linear_init(k2, cfg.d_model, cfg.n_kv_heads * hd, cfg.dtype, bias=cfg.qkv_bias),
        "wv": L.linear_init(k3, cfg.d_model, cfg.n_kv_heads * hd, cfg.dtype, bias=cfg.qkv_bias),
        "wo": L.linear_init(k4, cfg.n_heads * hd, cfg.d_model, cfg.dtype),
    }


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd)


def gqa_forward(cfg, p, x, positions, *, window: int = 0, causal: bool = True,
                backend: Optional[str] = None, return_cache: bool = False,
                kv_override=None, attn_constraint=None):
    """Full-sequence attention (train / prefill / encoder).

    ``kv_override``: (k, v) head tensors for cross-attention (already RoPE-free).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    q = _split_heads(L.linear(p["wq"], x), cfg.n_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta) if kv_override is None else q
    if kv_override is None:
        k = _split_heads(L.linear(p["wk"], x), cfg.n_kv_heads, hd)
        v = _split_heads(L.linear(p["wv"], x), cfg.n_kv_heads, hd)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    out = _grouped_flash(q, k, v, causal=causal, window=window, backend=backend,
                         attn_constraint=attn_constraint)
    y = L.linear(p["wo"], out.reshape(b, s, cfg.n_heads * hd))
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def _grouped_flash(q, k, v, *, causal, window, backend, attn_constraint=None):
    """q: (B,S,H,hd); k,v: (B,Sk,KH,hd) with H = KH * G.

    ``attn_constraint``: NamedSharding for the flattened (B*KH*G, S, hd)
    layout -- pinning (batch, heads) to (data, model) on the composite
    leading dim keeps the whole flash computation shard-local (EXPERIMENTS
    section Perf, iteration B4)."""
    b, s, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    # (B, KH, G, S, hd) -> flatten (B*KH*G) so each kv head serves G q heads.
    qg = q.transpose(0, 2, 1, 3).reshape(b, kh, g, s, hd)
    qf = qg.reshape(b * kh * g, s, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * kh, 1, sk, hd), g, axis=1) \
        .reshape(b * kh * g, sk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * kh, 1, sk, hd), g, axis=1) \
        .reshape(b * kh * g, sk, hd)
    if attn_constraint is not None:
        qf = jax.lax.with_sharding_constraint(qf, attn_constraint)
        kf = jax.lax.with_sharding_constraint(kf, attn_constraint)
        vf = jax.lax.with_sharding_constraint(vf, attn_constraint)
    of = ops.flash_attention(qf, kf, vf, causal=causal, window=window,
                             backend=backend)
    if attn_constraint is not None:
        of = jax.lax.with_sharding_constraint(of, attn_constraint)
    return of.reshape(b, kh, g, s, hd).reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def gqa_prefill_cache(cfg, smax: int, k, v, window: int, quant: bool = False):
    """Place prefill K/V into the (padded or ring) cache layout.

    Ring convention: position p lives at slot ``p % window`` (matches
    ``gqa_decode``); softmax attention is permutation-invariant so ring
    order never needs unwinding."""
    b, s = k.shape[0], k.shape[1]
    if window > 0:
        if s >= window:
            kk = jnp.roll(k[:, -window:], s % window, axis=1)
            vv = jnp.roll(v[:, -window:], s % window, axis=1)
        else:
            kk = jnp.pad(k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
            vv = jnp.pad(v, ((0, 0), (0, window - s), (0, 0), (0, 0)))
    else:
        pad = smax - s
        kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if quant:
        kq, ks = _kv_quantize(kk)
        vq, vs = _kv_quantize(vv)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return {"k": kk, "v": vv}


def _kv_quantize(k):
    """Per-(token, head) symmetric int8 quantization of a K/V slice."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gqa_decode(cfg, p, x, cache, pos, *, window: int = 0,
               backend: Optional[str] = None, kv_constraint=None):
    """Single-token decode. x: (B, 1, d); cache per conventions above;
    ``pos`` traced scalar = number of tokens already in the cache.

    Quantized caches (int8 + per-token-head scales, see ``kv_quant``) halve
    the decode memory term; dequantization fuses into the attention matmul.
    """
    b = x.shape[0]
    hd = cfg.hd
    q = _split_heads(L.linear(p["wq"], x), cfg.n_heads, hd)
    k = _split_heads(L.linear(p["wk"], x), cfg.n_kv_heads, hd)
    v = _split_heads(L.linear(p["wv"], x), cfg.n_kv_heads, hd)
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = L.apply_rope(q, posv, cfg.rope_theta)
    k = L.apply_rope(k, posv, cfg.rope_theta)
    if kv_constraint is not None:
        # A2 (EXPERIMENTS.md section Perf): align the written slice's
        # sharding with the cache so the dynamic_update_slice stays
        # shard-local instead of resharding cache tiles every layer.
        k = jax.lax.with_sharding_constraint(k, kv_constraint)
        v = jax.lax.with_sharding_constraint(v, kv_constraint)

    quant = "k_scale" in cache
    slot = jnp.mod(pos, window) if window > 0 else pos
    length = jnp.minimum(pos + 1, window) if window > 0 else pos + 1
    if quant:
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0))
        out = _grouped_decode(q, _kv_dequant(ck, cks, x.dtype),
                              _kv_dequant(cv, cvs, x.dtype), length)
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        # ring order does not matter for softmax attention (permutation
        # invariant); mask by live length.
        out = _grouped_decode(q, ck, cv, length)
        new_cache = {"k": ck, "v": cv}
    y = L.linear(p["wo"], out.reshape(b, 1, cfg.n_heads * hd))
    return y, new_cache


def _grouped_decode(q, ck, cv, length):
    """Grouped-query decode attention, einsum formulation (no KV head
    expansion in HBM). q: (B,1,H,hd); ck/cv: (B,S,KH,hd)."""
    b, _, h, hd = q.shape
    s, kh = ck.shape[1], ck.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (hd ** -0.5)
    kpos = jnp.arange(s)
    mask = kpos[None, None, None, :] < length
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, cv.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek)
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    m = cfg.mla
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    return {
        "wq_a": L.linear_init(ks[0], cfg.d_model, m.q_lora_rank, cfg.dtype),
        "q_norm": L.rmsnorm_init(m.q_lora_rank, cfg.dtype),
        "wq_b": L.linear_init(ks[1], m.q_lora_rank,
                              h * (m.qk_nope_head_dim + m.qk_rope_head_dim), cfg.dtype),
        "wkv_a": L.linear_init(ks[2], cfg.d_model,
                               m.kv_lora_rank + m.qk_rope_head_dim, cfg.dtype),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, cfg.dtype),
        "wkv_b": L.linear_init(ks[3], m.kv_lora_rank,
                               h * (m.qk_nope_head_dim + m.v_head_dim), cfg.dtype),
        "wo": L.linear_init(ks[4], h * m.v_head_dim, cfg.d_model, cfg.dtype),
    }


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = L.linear(p["wq_b"], L.rmsnorm(p["q_norm"], L.linear(p["wq_a"], x)))
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, x, positions):
    m = cfg.mla
    ckr = L.linear(p["wkv_a"], x)
    c, kr = jnp.split(ckr, [m.kv_lora_rank], axis=-1)
    c = L.rmsnorm(p["kv_norm"], c)
    kr = L.apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c, kr


def mla_forward(cfg, p, x, positions, *, backend=None, return_cache=False):
    """Training / prefill: reconstruct per-head K/V from the latent."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c, kr = _mla_latent(cfg, p, x, positions)
    kv = L.linear(p["wkv_b"], c).reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # flash over (B*H) rows; v dim differs from k dim -> xla blocked path
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, -1)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, -1)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, -1)
    of = ops.flash_attention(qf, kf, vf, causal=True, scale=scale,
                             backend="xla" if backend in (None, "pallas") else backend)
    out = of.reshape(b, h, s, m.v_head_dim).transpose(0, 2, 1, 3)
    y = L.linear(p["wo"], out.reshape(b, s, h * m.v_head_dim))
    if return_cache:
        return y, {"c": c, "kr": kr}
    return y


def mla_prefill_cache(cfg, smax, cache):
    pad = smax - cache["c"].shape[1]
    return {"c": jnp.pad(cache["c"], ((0, 0), (0, pad), (0, 0))),
            "kr": jnp.pad(cache["kr"], ((0, 0), (0, pad), (0, 0)))}


def mla_decode(cfg, p, x, cache, pos, *, backend=None):
    """Absorbed-latent decode: attention runs over the compressed latent
    cache (kv_lora + rope dims per position), never materializing per-head
    K/V for the whole context -- the MLA memory saving, done properly."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, posv)           # (B,1,H,*)
    c_new, kr_new = _mla_latent(cfg, p, x, posv)
    cc = jax.lax.dynamic_update_slice(cache["c"], c_new, (0, pos, 0))
    ckr = jax.lax.dynamic_update_slice(cache["kr"], kr_new, (0, pos, 0))

    wkv_b = p["wkv_b"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_head_dim]             # (r, H, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]             # (r, H, v)
    # absorb W_uk into q: q_eff (B,1,H,r)
    q_eff = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bthr,bsr->bhs", q_eff, cc.astype(jnp.float32))
    s_rope = jnp.einsum("bthd,bsd->bhs", q_rope.astype(jnp.float32),
                        ckr.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    mask = jnp.arange(cc.shape[1])[None, None, :] < (pos + 1)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w, cc.astype(jnp.float32))   # (B,H,r)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    y = L.linear(p["wo"], out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype))
    return y, {"c": cc, "kr": ckr}
