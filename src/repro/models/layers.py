"""Foundational functional layers (pure JAX, params as nested dicts).

Conventions:
* every ``*_init(key, ...)`` returns a params pytree of ``jnp`` arrays;
* every forward fn is ``f(params, x, ...) -> y`` and jit/scan/shard friendly;
* compute dtype follows the input; params are stored in ``cfg.dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------- linear
def linear_init(key, d_in: int, d_out: int, dtype="float32", bias: bool = False,
                scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), _dtype(dtype)) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), _dtype(dtype))
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------- norms
def rmsnorm_init(dim: int, dtype="float32"):
    return {"g": jnp.ones((dim,), _dtype(dtype))}


def rmsnorm(p, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype="float32"):
    return {"g": jnp.ones((dim,), _dtype(dtype)), "b": jnp.zeros((dim,), _dtype(dtype))}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, dim: int, dtype="float32"):
    return {"table": jax.random.normal(key, (vocab, dim), _dtype(dtype)) * 0.02}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied or untied output head: logits in float32 for stable loss."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T


# ---------------------------------------------------------------- MLPs
def swiglu_init(key, d_model: int, d_ff: int, dtype="float32"):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff, dtype),
        "up": linear_init(k2, d_model, d_ff, dtype),
        "down": linear_init(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))                # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token cross-entropy with ignore mask; logits float32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
