"""Mixture-of-experts FFN: shared + routed experts, top-k gating, capacity
dispatch (sort + scatter; honest top-k FLOPs, no dense all-expert compute).

Sharding strategies (distributed/sharding.py picks per mesh):
* "expert-TP": expert FF dims sharded over the model axis (default; clean
  GSPMD einsums);
* "EP": the expert axis sharded over the model axis -- the (E, C, d)
  dispatch buffer reshards token->expert, which GSPMD lowers to the
  all-to-all pair; this is the beyond-paper hillclimb lever for DeepSeek.

Router uses the fused kernel (kernels/moe_topk) on TPU, jnp elsewhere.
Padding experts (EP divisibility, e.g. qwen2-moe 60 -> 64) are masked out
of the softmax and never receive tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import layers as L


def moe_init(key, cfg):
    m = cfg.moe
    e = m.routed_total()
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, m.expert_ff
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.dtype(cfg.dtype)) * 0.02},
        "experts": {
            "gate": jax.random.normal(ks[1], (e, d, f), jnp.dtype(cfg.dtype)) * scale,
            "up": jax.random.normal(ks[2], (e, d, f), jnp.dtype(cfg.dtype)) * scale,
            "down": jax.random.normal(ks[3], (e, f, d), jnp.dtype(cfg.dtype)) * (1.0 / jnp.sqrt(f)),
        },
    }
    if m.n_shared > 0:
        p["shared"] = L.swiglu_init(ks[4], d, m.n_shared * f, cfg.dtype)
    return p


def moe_forward(cfg, p, x, *, capacity_factor: float = 1.25,
                backend: str | None = None):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = m.routed_total()
    xf = x.reshape(t, d)

    logits = xf @ p["router"]["w"].astype(xf.dtype)                  # (T, E)
    weights, idx = ops.moe_topk(logits, m.top_k, n_valid=m.n_routed,
                                backend=backend)                     # (T,k)
    weights = weights * m.router_scale

    # load-balance aux loss (Switch-style) over the valid experts
    probs = jax.nn.softmax(
        jnp.where(jnp.arange(e)[None, :] < m.n_routed,
                  logits.astype(jnp.float32), -1e30), axis=-1)
    me = jnp.mean(probs, axis=0)                                     # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = m.n_routed * jnp.sum(me * ce)

    # ---- capacity dispatch: sort tokens by expert, scatter to (E, C, d)
    cap = int(max(1, round(t * m.top_k * capacity_factor / e)))
    flat_eid = idx.reshape(-1)                                       # (T*k,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), m.top_k)
    order = jnp.argsort(flat_eid)
    eid_s = flat_eid[order]
    tok_s = flat_tok[order]
    w_s = flat_w[order]
    # position of each routed token within its expert's block
    group_sizes = jnp.bincount(eid_s, length=e)
    starts = jnp.cumsum(group_sizes) - group_sizes
    pos_s = jnp.arange(t * m.top_k) - starts[eid_s]
    keep = pos_s < cap                                               # drop overflow
    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[eid_s, jnp.where(keep, pos_s, 0)].add(
        jnp.where(keep[:, None], xf[tok_s], 0.0))

    # ---- expert compute (E, C, d) -> (E, C, d); honest top-k FLOPs
    w_exp = p["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_exp["gate"].astype(buf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_exp["up"].astype(buf.dtype))
    yexp = jnp.einsum("ecf,efd->ecd", h, w_exp["down"].astype(buf.dtype))

    # ---- combine back, weighted
    gathered = yexp[eid_s, jnp.where(keep, pos_s, 0)]                # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0) * w_s[:, None].astype(xf.dtype)
    y = jnp.zeros_like(xf).at[tok_s].add(gathered)

    if "shared" in p:
        y = y + L.swiglu(p["shared"], xf)
    return y.reshape(b, s, d), aux
