"""SSM mixers: xLSTM (mLSTM matrix memory + sLSTM scalar memory) and
mamba-2/SSD-style heads (hymba's parallel SSM path).

All sequence mixing runs through the chunkwise-parallel linear-attention
machinery (kernels/mlstm_scan): constant-size recurrent state, O(S) time,
MXU-shaped chunk matmuls -- the TPU-native formulation of both mLSTM and
SSD (DESIGN.md section 8). The sLSTM path is a per-channel linear
recurrence evaluated with an associative scan (no head-recurrent gate
connections -- simplification recorded in DESIGN.md).

Decode state conventions (per layer):
* mLSTM / SSD : {"c": (B, H, dk, dv) f32, "n": (B, H, dk) f32}
* sLSTM       : {"c": (B, d) f32, "n": (B, d) f32}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import layers as L


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    di = cfg.ssm.expand * d
    hd = di // h
    ks = jax.random.split(key, 7)
    return {
        "wq": L.linear_init(ks[0], d, di, cfg.dtype),
        "wk": L.linear_init(ks[1], d, di, cfg.dtype),
        "wv": L.linear_init(ks[2], d, di, cfg.dtype),
        "wi": L.linear_init(ks[3], d, h, cfg.dtype, bias=True),
        "wf": L.linear_init(ks[4], d, h, cfg.dtype, bias=True),
        "wo": L.linear_init(ks[5], di, d, cfg.dtype),
        "gate": L.linear_init(ks[6], d, di, cfg.dtype),
        "norm": L.rmsnorm_init(hd, cfg.dtype),
    }


def _mlstm_qkv(cfg, p, x):
    b, s, d = x.shape
    h = cfg.n_heads
    di = cfg.ssm.expand * d
    hd = di // h
    q = L.linear(p["wq"], x).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = L.linear(p["wk"], x).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = L.linear(p["wv"], x).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    logf = jax.nn.log_sigmoid(L.linear(p["wf"], x).astype(jnp.float32) + 2.0) \
        .transpose(0, 2, 1)                                   # (B,H,S)
    ig = jax.nn.sigmoid(L.linear(p["wi"], x).astype(jnp.float32)).transpose(0, 2, 1)
    return q, k, v, logf, ig, (b, s, h, hd, di)


def mlstm_forward(cfg, p, x, *, backend=None, return_state=False):
    q, k, v, logf, ig, (b, s, h, hd, di) = _mlstm_qkv(cfg, p, x)
    hseq = ops.mlstm_scan(q.reshape(b * h, s, hd), k.reshape(b * h, s, hd),
                          v.reshape(b * h, s, hd), logf.reshape(b * h, s),
                          ig.reshape(b * h, s), backend=backend)
    hseq = hseq.reshape(b, h, s, hd)
    hseq = L.rmsnorm(p["norm"], hseq).transpose(0, 2, 1, 3).reshape(b, s, di)
    y = L.linear(p["wo"], hseq * jax.nn.silu(L.linear(p["gate"], x)))
    if return_state:
        state = _mlstm_final_state(q, k, v, logf, ig)
        return y, state
    return y


def _mlstm_final_state(q, k, v, logf, ig):
    """Recompute the final (C, n) carry for decode continuation."""
    b, h, s, hd = k.shape
    la = jnp.cumsum(logf, axis=-1)                        # (B,H,S)
    total = la[..., -1:]
    w = ig * jnp.exp(total - la)                          # (B,H,S)
    c = jnp.einsum("bhs,bhsd,bhse->bhde", w, k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.einsum("bhs,bhsd->bhd", w, k.astype(jnp.float32))
    c = jnp.exp(total)[..., None] * 0.0 + c               # no initial state
    return {"c": c, "n": n}


def mlstm_decode(cfg, p, x, state):
    """Single-step recurrence. x: (B,1,d)."""
    q, k, v, logf, ig, (b, s, h, hd, di) = _mlstm_qkv(cfg, p, x)
    qt = q[:, :, 0].astype(jnp.float32) * (hd ** -0.5)    # (B,H,hd)
    kt = k[:, :, 0].astype(jnp.float32)
    vt = v[:, :, 0].astype(jnp.float32)
    f = jnp.exp(logf[..., 0])                             # (B,H)
    it = ig[..., 0]
    c = f[..., None, None] * state["c"] + it[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kt, vt)
    n = f[..., None] * state["n"] + it[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), 1.0)
    hvec = (num / den[..., None]).astype(x.dtype)         # (B,H,hd)
    hvec = L.rmsnorm(p["norm"], hvec).reshape(b, 1, di)
    y = L.linear(p["wo"], hvec * jax.nn.silu(L.linear(p["gate"], x)))
    return y, {"c": c, "n": n}


# ---------------------------------------------------------------------------
# SSD / mamba-2 heads (hymba parallel path)
# ---------------------------------------------------------------------------

def ssd_init(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    n = cfg.ssm.state_dim
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "wv": L.linear_init(ks[0], d, h * hd, cfg.dtype),      # u (value path)
        "wb": L.linear_init(ks[1], d, h * n, cfg.dtype),       # B (k analogue)
        "wc": L.linear_init(ks[2], d, h * n, cfg.dtype),       # C (q analogue)
        "wdt": L.linear_init(ks[3], d, h, cfg.dtype, bias=True),
        "wo": L.linear_init(ks[4], h * hd, d, cfg.dtype),
        "gate": L.linear_init(ks[5], d, h * hd, cfg.dtype),
        "a_log": jnp.zeros((h,), jnp.float32),                 # per-head decay rate
    }


def _ssd_proj(cfg, p, x):
    b, s, d = x.shape
    h = cfg.n_heads
    n = cfg.ssm.state_dim
    hd = cfg.hd
    v = L.linear(p["wv"], x).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    kb = L.linear(p["wb"], x).reshape(b, s, h, n).transpose(0, 2, 1, 3)
    qc = L.linear(p["wc"], x).reshape(b, s, h, n).transpose(0, 2, 1, 3)
    dt = jax.nn.softplus(L.linear(p["wdt"], x).astype(jnp.float32)).transpose(0, 2, 1)
    a = -jnp.exp(p["a_log"])[None, :, None]                    # (1,H,1) < 0
    logf = a * dt                                              # (B,H,S) log decay
    ig = dt                                                    # input weight
    return qc, kb, v, logf, ig, (b, s, h, n, hd)


def ssd_forward(cfg, p, x, *, backend=None, return_state=False):
    qc, kb, v, logf, ig, (b, s, h, n, hd) = _ssd_proj(cfg, p, x)
    hseq = ops.mlstm_scan(qc.reshape(b * h, s, n), kb.reshape(b * h, s, n),
                          v.reshape(b * h, s, hd), logf.reshape(b * h, s),
                          ig.reshape(b * h, s), backend=backend, scale=1.0)
    hseq = hseq.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    y = L.linear(p["wo"], hseq * jax.nn.silu(L.linear(p["gate"], x)))
    if return_state:
        la = jnp.cumsum(logf, axis=-1)
        total = la[..., -1:]
        w = ig * jnp.exp(total - la)
        c = jnp.einsum("bhs,bhsd,bhse->bhde", w, kb.astype(jnp.float32),
                       v.astype(jnp.float32))
        nn = jnp.einsum("bhs,bhsd->bhd", w, kb.astype(jnp.float32))
        return y, {"c": c, "n": nn}
    return y


def ssd_decode(cfg, p, x, state):
    qc, kb, v, logf, ig, (b, s, h, n, hd) = _ssd_proj(cfg, p, x)
    qt = qc[:, :, 0].astype(jnp.float32)
    kt = kb[:, :, 0].astype(jnp.float32)
    vt = v[:, :, 0].astype(jnp.float32)
    f = jnp.exp(logf[..., 0])
    it = ig[..., 0]
    c = f[..., None, None] * state["c"] + it[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", kt, vt)
    nn = f[..., None] * state["n"] + it[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, nn)), 1.0)
    hvec = (num / den[..., None]).astype(x.dtype).reshape(b, 1, h * hd)
    y = L.linear(p["wo"], hvec * jax.nn.silu(L.linear(p["gate"], x)))
    return y, {"c": c, "n": nn}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM scalar memory)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "wz": L.linear_init(ks[0], d, d, cfg.dtype, bias=True),
        "wi": L.linear_init(ks[1], d, d, cfg.dtype, bias=True),
        "wf": L.linear_init(ks[2], d, d, cfg.dtype, bias=True),
        "wout": L.linear_init(ks[3], d, d, cfg.dtype, bias=True),
        "proj": L.linear_init(ks[4], d, d, cfg.dtype),
    }


def _slstm_gates(p, x):
    z = jnp.tanh(L.linear(p["wz"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["wi"], x).astype(jnp.float32))
    f = jax.nn.sigmoid(L.linear(p["wf"], x).astype(jnp.float32) + 2.0)
    o = jax.nn.sigmoid(L.linear(p["wout"], x).astype(jnp.float32))
    return z, i, f, o


def slstm_forward(cfg, p, x, *, return_state=False):
    """Per-channel linear recurrence c_t = f c + i z, n_t = f n + i,
    h = o * c/n -- associative scan over time."""
    z, i, f, o = _slstm_gates(p, x)

    def combine(a, b):
        (fa, ca, na), (fb, cb, nb) = a, b
        return (fa * fb, fb * ca + cb, fb * na + nb)

    f_, c_, n_ = jax.lax.associative_scan(
        combine, (f, i * z, i), axis=1)
    hseq = o * c_ / jnp.maximum(jnp.abs(n_), 1.0)
    y = L.linear(p["proj"], hseq.astype(x.dtype))
    if return_state:
        return y, {"c": c_[:, -1], "n": n_[:, -1]}
    return y


def slstm_decode(cfg, p, x, state):
    z, i, f, o = _slstm_gates(p, x)
    c = f[:, 0] * state["c"] + i[:, 0] * z[:, 0]
    n = f[:, 0] * state["n"] + i[:, 0]
    h = o[:, 0] * c / jnp.maximum(jnp.abs(n), 1.0)
    y = L.linear(p["proj"], h[:, None].astype(x.dtype))
    return y, {"c": c, "n": n}
