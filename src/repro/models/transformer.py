"""Model assembly: segment plan, scan-over-layers, train/prefill/decode.

Every architecture compiles to a list of *segments* -- homogeneous runs of
layers executed with ``jax.lax.scan`` over stacked parameters (bounded HLO
size even for the 61-layer DeepSeek config), plus occasional "single"
layers where the stack is heterogeneous (hymba's three global-attention
layers, xLSTM's sLSTM blocks).

Modes:
* ``train_loss``  : full-sequence forward + causal CE (+ MoE aux loss)
* ``prefill``     : forward that also builds the per-layer caches
* ``decode_step`` : one token in, one logits row out, caches updated

Cache pytree mirrors the segment list; scanned segments stack their cache
leaves on a leading layer axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as A
from . import layers as L
from . import moe as M
from . import ssm as S


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str        # "scan" | "single"
    n: int
    mixer: str       # "attn" | "mla" | "hybrid" | "mlstm" | "slstm"
    ffn: str         # "swiglu" | "moe" | "none"
    window: int = 0
    cross: bool = False


def build_plan(cfg: ArchConfig) -> list:
    if cfg.family == "ssm":                     # xlstm: 5 mLSTM + 1 sLSTM per group
        k = cfg.ssm.slstm_every
        plan = []
        if k and cfg.n_layers >= k:
            groups = cfg.n_layers // k
            for _ in range(groups):
                plan.append(Segment("scan", k - 1, "mlstm", "none"))
                plan.append(Segment("single", 1, "slstm", "none"))
            rem = cfg.n_layers - groups * k
        else:
            rem = cfg.n_layers
        if rem:
            plan.append(Segment("scan", rem, "mlstm", "none"))
        return plan
    if cfg.family == "hybrid":                  # hymba
        gl = sorted(cfg.global_attn_layers)
        plan = []
        prev = 0
        for g in gl:
            if g > prev:
                plan.append(Segment("scan", g - prev, "hybrid", "swiglu",
                                    window=cfg.sliding_window))
            plan.append(Segment("single", 1, "hybrid", "swiglu", window=0))
            prev = g + 1
        if prev < cfg.n_layers:
            plan.append(Segment("scan", cfg.n_layers - prev, "hybrid", "swiglu",
                                window=cfg.sliding_window))
        return plan
    mixer = "mla" if cfg.mla is not None else "attn"
    cross = cfg.family == "audio"
    if cfg.moe is not None:
        plan = []
        if cfg.first_k_dense:
            plan.append(Segment("scan", cfg.first_k_dense, mixer, "swiglu", cross=cross))
        plan.append(Segment("scan", cfg.n_layers - cfg.first_k_dense, mixer,
                            "moe", cross=cross))
        return plan
    return [Segment("scan", cfg.n_layers, mixer, "swiglu", cross=cross)]


# ---------------------------------------------------------------------------
# layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig, seg: Segment):
    ks = jax.random.split(key, 8)
    p = {"norm1": L.rmsnorm_init(cfg.d_model, cfg.dtype)}
    if seg.mixer in ("attn", "hybrid"):
        p["attn"] = A.gqa_init(ks[0], cfg)
    if seg.mixer == "hybrid":
        p["ssd"] = S.ssd_init(ks[1], cfg)
    if seg.mixer == "mla":
        p["attn"] = A.mla_init(ks[0], cfg)
    if seg.mixer == "mlstm":
        p["mixer"] = S.mlstm_init(ks[2], cfg)
    if seg.mixer == "slstm":
        p["mixer"] = S.slstm_init(ks[2], cfg)
    if seg.cross:
        p["normc"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
        p["cross"] = A.gqa_init(ks[3], cfg)
    if seg.ffn != "none":
        p["norm2"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
    if seg.ffn == "swiglu":
        p["ffn"] = L.swiglu_init(ks[4], cfg.d_model, cfg.d_ff, cfg.dtype)
    elif seg.ffn == "moe":
        p["ffn"] = M.moe_init(ks[4], cfg)
    return p


def _apply_mixer_seq(cfg, seg, lp, xn, positions, *, backend, want_cache,
                     smax=0, kv_quant=False, attn_constraint=None,
                     shardmap_attn=None):
    """Full-sequence mixer; returns (y, cache_leaf or None)."""
    if seg.mixer == "attn":
        if shardmap_attn is not None:
            y = shardmap_attn(lp["attn"], xn, positions, seg.window)
            if want_cache:
                # cache K/V via the plain projections (cheap vs attention)
                k = A._split_heads(L.linear(lp["attn"]["wk"], xn),
                                   cfg.n_kv_heads, cfg.hd)
                v = A._split_heads(L.linear(lp["attn"]["wv"], xn),
                                   cfg.n_kv_heads, cfg.hd)
                k = L.apply_rope(k, positions, cfg.rope_theta)
                return y, A.gqa_prefill_cache(cfg, smax, k, v, seg.window,
                                              quant=kv_quant)
            return y, None
        if want_cache:
            y, kv = A.gqa_forward(cfg, lp["attn"], xn, positions,
                                  window=seg.window, backend=backend,
                                  return_cache=True,
                                  attn_constraint=attn_constraint)
            return y, A.gqa_prefill_cache(cfg, smax, kv["k"], kv["v"],
                                          seg.window, quant=kv_quant)
        return A.gqa_forward(cfg, lp["attn"], xn, positions,
                             window=seg.window, backend=backend,
                             attn_constraint=attn_constraint), None
    if seg.mixer == "mla":
        if want_cache:
            y, c = A.mla_forward(cfg, lp["attn"], xn, positions,
                                 backend=backend, return_cache=True)
            return y, A.mla_prefill_cache(cfg, smax, c)
        return A.mla_forward(cfg, lp["attn"], xn, positions, backend=backend), None
    if seg.mixer == "hybrid":
        if want_cache:
            ya, kv = A.gqa_forward(cfg, lp["attn"], xn, positions,
                                   window=seg.window, backend=backend,
                                   return_cache=True)
            ys, st = S.ssd_forward(cfg, lp["ssd"], xn, backend=backend,
                                   return_state=True)
            cache = {"kv": A.gqa_prefill_cache(
                cfg, smax, kv["k"], kv["v"], seg.window, quant=kv_quant),
                "ssd": st}
            return 0.5 * (ya + ys), cache
        ya = A.gqa_forward(cfg, lp["attn"], xn, positions,
                           window=seg.window, backend=backend)
        ys = S.ssd_forward(cfg, lp["ssd"], xn, backend=backend)
        return 0.5 * (ya + ys), None
    if seg.mixer == "mlstm":
        if want_cache:
            return S.mlstm_forward(cfg, lp["mixer"], xn, backend=backend,
                                   return_state=True)
        return S.mlstm_forward(cfg, lp["mixer"], xn, backend=backend), None
    if seg.mixer == "slstm":
        if want_cache:
            return S.slstm_forward(cfg, lp["mixer"], xn, return_state=True)
        return S.slstm_forward(cfg, lp["mixer"], xn), None
    raise ValueError(seg.mixer)


def _apply_layer_seq(cfg, seg, lp, carry, positions, *, backend,
                     want_cache=False, smax=0, enc_out=None, enc_cache=False,
                     capacity_factor=1.25, kv_quant=False, act_constraint=None,
                     attn_constraint=None, shardmap_attn=None):
    """(x, aux) -> (x', aux'), cache_leaf."""
    x, aux = carry
    if act_constraint is not None:
        x = jax.lax.with_sharding_constraint(x, act_constraint)
    xn = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    y, cache = _apply_mixer_seq(cfg, seg, lp, xn, positions, backend=backend,
                                want_cache=want_cache, smax=smax,
                                kv_quant=kv_quant,
                                attn_constraint=attn_constraint,
                                shardmap_attn=shardmap_attn)
    x = x + y
    if seg.cross and enc_out is not None:
        xc = L.rmsnorm(lp["normc"], x, cfg.norm_eps)
        ck = A._split_heads(L.linear(lp["cross"]["wk"], enc_out),
                            cfg.n_kv_heads, cfg.hd)
        cv = A._split_heads(L.linear(lp["cross"]["wv"], enc_out),
                            cfg.n_kv_heads, cfg.hd)
        yc = A.gqa_forward(cfg, lp["cross"], xc, positions, causal=False,
                           backend=backend, kv_override=(ck, cv))
        x = x + yc
        if want_cache:
            cache = {"self": cache, "cross_k": ck, "cross_v": cv}
    if seg.ffn == "swiglu":
        x = x + L.swiglu(lp["ffn"], L.rmsnorm(lp["norm2"], x, cfg.norm_eps))
    elif seg.ffn == "moe":
        y, a = M.moe_forward(cfg, lp["ffn"],
                             L.rmsnorm(lp["norm2"], x, cfg.norm_eps),
                             backend=backend, capacity_factor=capacity_factor)
        x = x + y
        aux = aux + a
    return (x, aux), cache


def _apply_layer_decode(cfg, seg, lp, carry, cache, pos, *, backend,
                        capacity_factor=2.0, kv_constraint=None):
    x, aux = carry
    self_cache = cache["self"] if seg.cross else cache
    xn = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if seg.mixer == "attn":
        y, new_cache = A.gqa_decode(cfg, lp["attn"], xn, self_cache, pos,
                                    window=seg.window, backend=backend,
                                    kv_constraint=kv_constraint)
    elif seg.mixer == "mla":
        y, new_cache = A.mla_decode(cfg, lp["attn"], xn, self_cache, pos,
                                    backend=backend)
    elif seg.mixer == "hybrid":
        ya, kv = A.gqa_decode(cfg, lp["attn"], xn, self_cache["kv"], pos,
                              window=seg.window, backend=backend)
        ys, st = S.ssd_decode(cfg, lp["ssd"], xn, self_cache["ssd"])
        y, new_cache = 0.5 * (ya + ys), {"kv": kv, "ssd": st}
    elif seg.mixer == "mlstm":
        y, new_cache = S.mlstm_decode(cfg, lp["mixer"], xn, self_cache)
    elif seg.mixer == "slstm":
        y, new_cache = S.slstm_decode(cfg, lp["mixer"], xn, self_cache)
    else:
        raise ValueError(seg.mixer)
    x = x + y
    if seg.cross:
        # cross-attend to the cached encoder K/V (computed at prefill)
        ck, cv = cache["cross_k"], cache["cross_v"]
        xc = L.rmsnorm(lp["normc"], x, cfg.norm_eps)
        yc = A.gqa_forward(cfg, lp["cross"], xc,
                           jnp.zeros((x.shape[0], 1), jnp.int32),
                           causal=False, backend=backend, kv_override=(ck, cv))
        x = x + yc
        new_cache = {"self": new_cache, "cross_k": ck, "cross_v": cv}
    if seg.ffn == "swiglu":
        x = x + L.swiglu(lp["ffn"], L.rmsnorm(lp["norm2"], x, cfg.norm_eps))
    elif seg.ffn == "moe":
        y, a = M.moe_forward(cfg, lp["ffn"],
                             L.rmsnorm(lp["norm2"], x, cfg.norm_eps),
                             backend=backend, capacity_factor=capacity_factor)
        x = x + y
        aux = aux + a
    return (x, aux), new_cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model:
    """Decoder LM / enc-dec / VLM backbone built from an ArchConfig."""

    def __init__(self, cfg: ArchConfig, backend: Optional[str] = None,
                 capacity_factor: Optional[float] = None, unroll: bool = False,
                 kv_quant: bool = False, act_constraint=None):
        self.cfg = cfg
        self.backend = backend
        self.capacity_factor = capacity_factor   # None -> mode defaults
        # Perf levers (EXPERIMENTS.md section Perf): int8 KV cache; explicit
        # activation sharding constraint (NamedSharding) at block boundaries.
        self.kv_quant = kv_quant
        self.act_constraint = act_constraint
        self.kv_update_constraint = None   # A2 lever: shard-local cache writes
        self.attn_layout_constraint = None  # B4 lever: head-sharded flash layout
        self.shardmap_attn = None           # B5 lever: explicit shard_map mixer
        # unroll=True applies scanned segments layer-by-layer (same stacked
        # param/cache layout). The dry-run uses it so XLA cost analysis sees
        # every layer (while-loop bodies are costed once, not x trip-count).
        self.unroll = unroll
        self.plan = build_plan(cfg)

    def _cf(self, default: float) -> float:
        return self.capacity_factor if self.capacity_factor is not None else default

    # ------------------------------------------------------------- params
    def init_params(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, len(self.plan) + 4)
        segs = []
        for i, seg in enumerate(self.plan):
            if seg.kind == "scan":
                lkeys = jax.random.split(keys[i], seg.n)
                segs.append(jax.vmap(lambda k: _layer_init(k, cfg, seg))(lkeys))
            else:
                segs.append(_layer_init(keys[i], cfg, seg))
        params = {
            "embed": L.embedding_init(keys[-1], cfg.vocab_size, cfg.d_model, cfg.dtype),
            "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "segments": segs,
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.linear_init(keys[-2], cfg.d_model,
                                              cfg.vocab_size, cfg.dtype)
        if cfg.encoder_layers:
            ekeys = jax.random.split(keys[-3], 2)
            eseg = Segment("scan", cfg.encoder_layers, "attn", "swiglu")
            elkeys = jax.random.split(ekeys[0], cfg.encoder_layers)
            params["encoder"] = {
                "layers": jax.vmap(lambda k: _layer_init(k, cfg, eseg))(elkeys),
                "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            }
        return params

    # ------------------------------------------------------------ helpers
    def _logits(self, params, x):
        x = L.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            return L.unembed(params["embed"], x)
        return L.linear(params["lm_head"], x).astype(jnp.float32)

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        if cfg.vision_tokens and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([vis, x[:, cfg.vision_tokens:]], axis=1)
        return x

    def _encode(self, params, frames):
        """Encoder stack over stub frame embeddings (audio frontend stub)."""
        cfg = self.cfg
        enc = params["encoder"]
        positions = jnp.arange(frames.shape[1])[None, :]
        eseg = Segment("scan", cfg.encoder_layers, "attn", "swiglu")

        def body(carry, lp):
            x, aux = carry
            xn = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
            y = A.gqa_forward(cfg, lp["attn"], xn, positions, causal=False,
                              backend=self.backend)
            x = x + y
            x = x + L.swiglu(lp["ffn"], L.rmsnorm(lp["norm2"], x, cfg.norm_eps))
            return (x, aux), None

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, _), _ = jax.lax.scan(fn, (frames.astype(jnp.dtype(cfg.dtype)), 0.0),
                                 enc["layers"])
        return L.rmsnorm(enc["final_norm"], x, cfg.norm_eps)

    def _backbone_seq(self, params, x, positions, *, want_cache, smax,
                      enc_out=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        caches = []
        carry = (x, aux)
        for seg, sp in zip(self.plan, params["segments"]):
            if seg.kind == "scan":
                def body(c, lp, seg=seg):
                    c2, cache = _apply_layer_seq(
                        cfg, seg, lp, c, positions, backend=self.backend,
                        want_cache=want_cache, smax=smax, enc_out=enc_out,
                        enc_cache=True, capacity_factor=self._cf(1.25),
                        kv_quant=self.kv_quant,
                        act_constraint=self.act_constraint,
                        attn_constraint=self.attn_layout_constraint,
                        shardmap_attn=self.shardmap_attn)
                    return c2, cache
                fn = jax.checkpoint(body) if cfg.remat else body
                if self.unroll:
                    carry, seg_cache = _unrolled_scan(fn, carry, sp, seg.n)
                else:
                    carry, seg_cache = jax.lax.scan(fn, carry, sp)
            else:
                carry, seg_cache = _apply_layer_seq(
                    cfg, seg, sp, carry, positions, backend=self.backend,
                    want_cache=want_cache, smax=smax, enc_out=enc_out,
                    enc_cache=True, capacity_factor=self._cf(1.25),
                    kv_quant=self.kv_quant,
                    act_constraint=self.act_constraint,
                    attn_constraint=self.attn_layout_constraint,
                    shardmap_attn=self.shardmap_attn)
            caches.append(seg_cache)
        return carry, caches

    # -------------------------------------------------------------- train
    def train_loss(self, params, batch):
        """batch: tokens (B,S), labels (B,S) [+ frames / vision_embeds]."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch["frames"])
        (x, aux), _ = self._backbone_seq(params, x, positions,
                                         want_cache=False, smax=0,
                                         enc_out=enc_out)
        logits = self._logits(params, x)
        loss = L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return loss + 0.01 * aux, {"loss": loss, "aux": aux}

    # ------------------------------------------------------------ prefill
    def prefill(self, params, batch, smax: int):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch["frames"])
        (x, _), caches = self._backbone_seq(params, x, positions,
                                            want_cache=True, smax=smax,
                                            enc_out=enc_out)
        logits = self._logits(params, x[:, -1:])
        return logits, caches

    def prefill_batch(self, params, batch, smax: int):
        """Batched ragged prefill for the serving engine's batched
        admission: one padded forward over B right-padded prompts.

        ``batch``: ``tokens`` (B, S) int32 right-padded, ``lengths`` (B,)
        int32 true prompt lengths.  Returns ``(logits (B, 1, V), caches)``
        where row ``i``'s logits are taken at position ``lengths[i]-1``
        (the last *real* token, not the padded tail).  Cache positions
        beyond a row's length hold pad-token K/V -- the same contamination
        class as the pool's zero rows, tolerated because decode attends
        under a causal mask up to the row's own length.
        """
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch["frames"])
        (x, _), caches = self._backbone_seq(params, x, positions,
                                            want_cache=True, smax=smax,
                                            enc_out=enc_out)
        last = (batch["lengths"].astype(jnp.int32) - 1)[:, None, None]
        idx = jnp.broadcast_to(last, (x.shape[0], 1, x.shape[2]))
        x_last = jnp.take_along_axis(x, idx, axis=1)       # (B, 1, D)
        logits = self._logits(params, x_last)
        return logits, caches

    # ------------------------------------------------------------- decode
    def decode_step(self, params, caches, token, pos):
        """token: (B, 1) int32; pos: traced scalar; caches from prefill."""
        cfg = self.cfg
        x = L.embed(params["embed"], token)
        aux = jnp.zeros((), jnp.float32)
        carry = (x, aux)
        new_caches = []
        for seg, sp, sc in zip(self.plan, params["segments"], caches):
            if seg.kind == "scan":
                def body(c, xs, seg=seg):
                    lp, cache = xs
                    c2, nc = _apply_layer_decode(
                        cfg, seg, lp, c, cache, pos, backend=self.backend,
                        capacity_factor=self._cf(2.0),
                        kv_constraint=self.kv_update_constraint)
                    return c2, nc
                if self.unroll:
                    carry, seg_cache = _unrolled_scan(body, carry, (sp, sc), seg.n)
                else:
                    carry, seg_cache = jax.lax.scan(body, carry, (sp, sc))
            else:
                carry, seg_cache = _apply_layer_decode(
                    cfg, seg, sp, carry, sc, pos, backend=self.backend,
                    capacity_factor=self._cf(2.0),
                    kv_constraint=self.kv_update_constraint)
            new_caches.append(seg_cache)
        logits = self._logits(params, carry[0])
        return logits, new_caches

    # ---------------------------------------------------------- cache spec
    def init_cache(self, batch_size: int, smax: int, dtype=None):
        """Zero caches (or use shapes for ShapeDtypeStruct via tree_map)."""
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        caches = []
        for seg in self.plan:
            leaf = self._seg_cache_leaf(seg, batch_size, smax, dt)
            if seg.kind == "scan":
                leaf = jax.tree.map(
                    lambda a: jnp.zeros((seg.n,) + a.shape, a.dtype), leaf)
            caches.append(leaf)
        return caches

    def cache_pspecs(self, mesh, batch_size: int, smax: int):
        """PartitionSpec tree matching init_cache: batch over data axes
        (sequence for batch-1 long context), head dims over model. The
        leading layer dim of scanned segments is never sharded."""
        shapes = jax.eval_shape(lambda: self.init_cache(batch_size, smax))
        out = []
        for seg, seg_shapes in zip(self.plan, shapes):
            scanned = seg.kind == "scan"
            out.append(jax.tree_util.tree_map_with_path(
                lambda path, leaf, seg=seg, sc=scanned:
                    _cache_leaf_pspec(seg, path, leaf.shape, mesh, sc),
                seg_shapes))
        return out

    def _seg_cache_leaf(self, seg: Segment, b: int, smax: int, dt):
        cfg = self.cfg
        kh, hd = cfg.n_kv_heads, cfg.hd
        h = cfg.n_heads
        if seg.mixer == "attn":
            s = seg.window if seg.window else smax
            if self.kv_quant:
                leaf = {"k": jnp.zeros((b, s, kh, hd), jnp.int8),
                        "v": jnp.zeros((b, s, kh, hd), jnp.int8),
                        "k_scale": jnp.zeros((b, s, kh), jnp.float32),
                        "v_scale": jnp.zeros((b, s, kh), jnp.float32)}
            else:
                leaf = {"k": jnp.zeros((b, s, kh, hd), dt),
                        "v": jnp.zeros((b, s, kh, hd), dt)}
        elif seg.mixer == "mla":
            m = cfg.mla
            leaf = {"c": jnp.zeros((b, smax, m.kv_lora_rank), dt),
                    "kr": jnp.zeros((b, smax, m.qk_rope_head_dim), dt)}
        elif seg.mixer == "hybrid":
            s = seg.window if seg.window else smax
            n = cfg.ssm.state_dim
            leaf = {"kv": {"k": jnp.zeros((b, s, kh, hd), dt),
                           "v": jnp.zeros((b, s, kh, hd), dt)},
                    "ssd": {"c": jnp.zeros((b, h, n, hd), jnp.float32),
                            "n": jnp.zeros((b, h, n), jnp.float32)}}
        elif seg.mixer == "mlstm":
            di = cfg.ssm.expand * cfg.d_model
            hdm = di // h
            leaf = {"c": jnp.zeros((b, h, hdm, hdm), jnp.float32),
                    "n": jnp.zeros((b, h, hdm), jnp.float32)}
        elif seg.mixer == "slstm":
            leaf = {"c": jnp.zeros((b, cfg.d_model), jnp.float32),
                    "n": jnp.zeros((b, cfg.d_model), jnp.float32)}
        else:
            raise ValueError(seg.mixer)
        if seg.cross:
            leaf = {"self": leaf,
                    "cross_k": jnp.zeros((b, cfg.encoder_len, kh, hd), dt),
                    "cross_v": jnp.zeros((b, cfg.encoder_len, kh, hd), dt)}
        return leaf


def _unrolled_scan(body, carry, xs, n):
    """Python-level scan (same semantics as lax.scan, stacked xs/ys)."""
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _cache_leaf_pspec(seg: Segment, leaf_path: tuple, shape: tuple,
                      mesh, scanned: bool):
    """PartitionSpec for one cache leaf (see Model.cache_pspecs)."""
    from jax.sharding import PartitionSpec as P
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp = mesh.shape["model"] if "model" in names else 1
    off = 1 if scanned else 0
    spec = [None] * len(shape)
    b = shape[off]
    if b >= dp_size and b % dp_size == 0 and b > 1:
        spec[off] = dp if len(dp) > 1 else dp[0]
    elif len(shape) > off + 1:
        # long-context batch-1 decode: shard the sequence dim instead
        s_dim = off + 1
        if shape[s_dim] >= 4096 and shape[s_dim] % dp_size == 0:
            spec[s_dim] = dp if len(dp) > 1 else dp[0]
    # shard a heads-like dim over model if it divides
    for d in range(len(shape) - 2, off, -1):
        if spec[d] is None and tp > 1 and shape[d] % tp == 0 and shape[d] >= tp:
            spec[d] = "model"
            break
    return P(*spec)


def make_model(name_or_cfg, backend: Optional[str] = None) -> Model:
    from ..configs.base import get_arch
    cfg = name_or_cfg if isinstance(name_or_cfg, ArchConfig) else get_arch(name_or_cfg)
    return Model(cfg, backend=backend)
