"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory term     = HLO_bytes / HBM_bw                (per chip)
    collective term = collective_bytes / link_bw        (per chip)

FLOPs/bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
per-device module; collective bytes are parsed from the optimized HLO text
(sum of operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).

Hardware model: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[4,1024,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?P<types>\(?[a-z0-9\[\],{}\s]+?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (result-shape bytes, deduplicating
    the -start/-done pairs of async collectives)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue                      # counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("types"))
        out[op] += nbytes
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    flops: float                  # per-device HLO FLOPs
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: float             # per-device collective bytes
    model_flops: float = 0.0      # 6*N*D (analytic, per device share)
    coll_detail: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_time(self) -> float:
        """Ideal overlapped execution: bounded by the slowest term."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization upper bound at the roofline time."""
        if self.roofline_time <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.roofline_time

    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "mfu_bound": self.mfu_bound,
            "useful_flops_ratio": self.useful_flops_ratio(),
            "coll_detail": {k: v for k, v in self.coll_detail.items()
                            if k != "counts"},
            "coll_counts": self.coll_detail.get("counts", {}),
        }


def analyze(compiled, model_flops_per_device: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=raw_bytes,
                    coll_bytes=float(coll["total"]),
                    model_flops=model_flops_per_device, coll_detail=coll)


def analytic_memory_bytes(cfg, shape, arg_bytes: float, out_bytes: float,
                          n_devices: int) -> float:
    """Analytic per-device HBM traffic per step.

    XLA's ``bytes accessed`` on the CPU backend sums every op's operands
    with no TPU-grade fusion, overstating HBM traffic by an order of
    magnitude; this analytic estimate is what the roofline memory term
    uses (the raw HLO number is kept in the table for reference).

    train   : params read fwd+bwd + grad write + opt m/v read/write
              (~2.5x resident argument bytes) + remat activation traffic
              (~12 x tokens x d x L x 2B: fwd save + bwd recompute + reads)
    prefill : params + activations (~6x) + cache writes (output bytes)
    decode  : params + full cache read (= argument bytes) + small writes
    """
    d, L = cfg.d_model, cfg.n_layers
    tokens_loc = shape.global_batch * shape.seq_len / n_devices
    if shape.kind == "train":
        return 2.5 * arg_bytes + 12.0 * tokens_loc * d * L * 2.0
    if shape.kind == "prefill":
        return arg_bytes + 6.0 * tokens_loc * d * L * 2.0 + out_bytes
    return arg_bytes + out_bytes / max(shape.seq_len, 1)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def count_params(shapes_tree) -> int:
    import jax
    return sum(int(__import__("numpy").prod(l.shape))
               for l in jax.tree.leaves(shapes_tree))


def active_params(cfg, total_params: int) -> int:
    """MoE: only shared + top-k routed experts are active per token."""
    if cfg.moe is None:
        return total_params
    m = cfg.moe
    e = m.routed_total()
    # per-layer routed expert params
    per_expert = 3 * cfg.d_model * m.expert_ff
    n_moe_layers = cfg.n_layers - cfg.first_k_dense
    routed_total = e * per_expert * n_moe_layers
    routed_active = m.top_k * per_expert * n_moe_layers
    return total_params - routed_total + routed_active


def _attn_flops_per_token(cfg, ctx: float) -> float:
    """Score + AV matmul FLOPs per token at effective context ``ctx``."""
    d_attn = cfg.n_heads * cfg.hd
    n_attn = cfg.n_layers if cfg.family != "ssm" else 0
    per = 4.0 * d_attn * ctx * n_attn
    if cfg.family == "hybrid" and cfg.ssm is not None:
        # SSD heads: state update + readout ~ 4 * n * hd per head per token
        per += 4.0 * cfg.ssm.state_dim * cfg.hd * cfg.n_heads * cfg.n_layers
    if cfg.family == "ssm" and cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        hd = di // cfg.n_heads
        per += 4.0 * hd * hd * cfg.n_heads * cfg.n_layers   # matrix memory
    return per


def attn_model_flops(cfg, shape, n_devices: int) -> float:
    """Per-device analytic attention FLOPs for the whole step (the part the
    blocked-scan flash implementation hides from XLA cost analysis)."""
    s, b = shape.seq_len, shape.global_batch
    if shape.kind in ("train", "prefill"):
        ctx = (min(s, cfg.sliding_window) if cfg.sliding_window else s) / 2.0
        per = _attn_flops_per_token(cfg, ctx)
        mult = 3.0 if shape.kind == "train" else 1.0
        return mult * b * s * per / n_devices
    ctx = min(s, cfg.sliding_window) if cfg.sliding_window else s
    per = _attn_flops_per_token(cfg, ctx)
    if cfg.family == "hybrid":
        d_attn = cfg.n_heads * cfg.hd
        per += 4.0 * d_attn * (s - cfg.sliding_window) * len(cfg.global_attn_layers)
    return b * per / n_devices


def model_flops(cfg, shape, params_total: int, n_devices: int) -> float:
    """Analytic useful FLOPs per device: 6*N*D train / 2*N*D forward over
    matmul params, plus attention context terms and the LM head where it is
    actually computed (prefill emits last-position logits only)."""
    n_act = active_params(cfg, params_total)
    vocab_d = cfg.vocab_size * cfg.d_model
    # Embedding gather costs ~no FLOPs. The unembed matmul costs 2*vocab_d
    # per logits-position whether the head is tied (reuses the table) or a
    # separate parameter -- n_body excludes both.
    n_body = n_act - vocab_d - (0 if cfg.tie_embeddings else vocab_d)
    head = 2.0 * vocab_d
    s = shape.seq_len
    b = shape.global_batch
    attn = attn_model_flops(cfg, shape, n_devices) * n_devices
    if shape.kind == "train":
        tokens = b * s
        total = 3.0 * tokens * (2.0 * n_body + head) + attn
    elif shape.kind == "prefill":
        tokens = b * s
        total = tokens * 2.0 * n_body + b * head + attn
    else:                                    # decode: one token per sequence
        total = b * (2.0 * n_body + head) + attn
    return total / n_devices
