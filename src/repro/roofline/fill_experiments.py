"""Fill EXPERIMENTS.md placeholders from results/dryrun.json + perf.json.

  PYTHONPATH=src python -m repro.roofline.fill_experiments
"""
from __future__ import annotations

import json
import os

from . import report as R
from . import analysis as RA
from ..configs.base import SHAPES, get_arch


def perf_table(path="results/perf.json") -> str:
    if not os.path.exists(path):
        return "(pending: run `python -m repro.roofline.hillclimb`)\n"
    with open(path) as f:
        perf = json.load(f)
    lines = ["| cell | variant | compute | memory (analytic) | collective | "
             "args GB/dev | Δ dominant |",
             "|---|---|---|---|---|---|---|"]
    base: dict = {}
    for key, res in perf.items():
        if res.get("status") != "ok":
            lines.append(f"| {key} | — | ERROR {res.get('error','')[:60]} | | | | |")
            continue
        arch, shape_name, variant = key.split("|")
        cfg = get_arch(arch)
        shape = SHAPES[shape_name]
        n_dev = res["n_devices"]
        r = res["roofline"]
        attn = RA.attn_model_flops(cfg, shape, n_dev)
        t_c = (r["flops"] + attn) / RA.PEAK_FLOPS
        mem = RA.analytic_memory_bytes(cfg, shape,
                                       res["memory"]["argument_bytes"],
                                       res["memory"]["output_bytes"], n_dev)
        t_m = mem / RA.HBM_BW
        t_x = r["coll_bytes"] / RA.ICI_BW
        cell = f"{arch}×{shape_name}"
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])
        delta = ""
        if variant == "baseline":
            base[cell] = dom
        elif cell in base:
            b = base[cell][1]
            delta = f"{(dom[1]-b)/b*100:+.0f}% vs baseline"
        lines.append(
            f"| {cell} | {variant} | {t_c*1e3:.2f} ms | {t_m*1e3:.1f} ms "
            f"| {t_x*1e3:.1f} ms | {res['memory']['argument_bytes']/2**30:.2f} "
            f"| {dom[0]} {dom[1]*1e3:.1f} ms {delta} |")
    return "\n".join(lines) + "\n"


def main() -> None:
    results = R.load("results/dryrun.json")
    if os.path.exists("results/dryrun_mp.json"):
        mp = R.load("results/dryrun_mp.json")
        results.update({k: v for k, v in mp.items() if k not in results
                        or results[k].get("status") != "ok"})
    rows = R.roofline_rows(results)
    table = R.markdown_table(rows)
    summary = R.dryrun_summary(results)

    notes = []
    worst = sorted(rows, key=lambda r: r["useful"])[:3]
    collb = [r for r in rows if r["bottleneck"] == "collective"]
    notes.append("**Bottleneck census (single-pod):** "
                 + ", ".join(f"{b}: {sum(1 for r in rows if r['bottleneck']==b)}"
                             for b in ("compute", "memory", "collective")) + ".")
    notes.append("**Lowest useful-FLOPs ratio:** "
                 + ", ".join(f"{r['arch']}×{r['shape']} ({r['useful']:.2f})"
                             for r in worst) + ".")
    if collb:
        top = max(collb, key=lambda r: r["t_collective_ms"])
        notes.append(f"**Most collective-bound:** {top['arch']}×{top['shape']} "
                     f"({top['t_collective_ms']:.0f} ms of collectives/step).")
    notes_md = "\n\n".join(notes) + "\n"

    import re

    def put(text, name, content):
        pat = re.compile(f"<!-- BEGIN:{name} -->.*?<!-- END:{name} -->", re.S)
        repl = f"<!-- BEGIN:{name} -->\n{content}\n<!-- END:{name} -->"
        if pat.search(text):
            return pat.sub(lambda _m: repl, text)
        return text.replace(f"<!-- {name} -->", repl)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = put(text, "DRYRUN-SUMMARY", summary)
    text = put(text, "ROOFLINE-TABLE", table)
    text = put(text, "ROOFLINE-NOTES", notes_md)
    text = put(text, "PERF-TABLE", perf_table())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
