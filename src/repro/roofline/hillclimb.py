import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Performance hillclimbing driver (EXPERIMENTS.md section Perf).

Re-lowers the three selected (arch x shape) cells with candidate changes and
records before/after roofline terms into results/perf.json. Each entry in
PLAN is one hypothesis -> change -> measure iteration; the narrative
(napkin math, confirmed/refuted) lives in EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.roofline.hillclimb [--only <cell>]
"""

import argparse
import json
import time
import traceback

PLAN = [
    # cell 1: worst useful-FLOPs fraction / serving hot path (memory-bound)
    ("stablelm-3b", "decode_32k", "baseline", {}),
    ("stablelm-3b", "decode_32k", "kv_int8", {"kv_quant": True}),
    ("stablelm-3b", "decode_32k", "kv_int8_local",
     {"kv_quant": True, "kv_local_update": True}),
    # cell 2: most collective-bound
    ("granite-8b", "prefill_32k", "baseline", {}),
    ("granite-8b", "prefill_32k", "act_dp", {"act_spec": ("data", None, None)}),
    ("granite-8b", "prefill_32k", "act_seqshard",
     {"act_spec": ("data", "model", None)}),
    ("granite-8b", "prefill_32k", "act_hidden",
     {"act_spec": ("data", None, "model")}),
    ("granite-8b", "prefill_32k", "attn_layout",
     {"attn_layout": True, "act_spec": ("data", None, None)}),
    ("granite-8b", "prefill_32k", "shardmap_attn",
     {"shardmap_attn": True, "act_spec": ("data", None, None)}),
    ("granite-8b", "train_4k", "baseline", {}),
    ("granite-8b", "train_4k", "shardmap_attn",
     {"shardmap_attn": True, "act_spec": ("data", None, None)}),
    # cell 3: the paper-representative large-scale mixed-deployment trainer
    # (MoE). qwen2-moe is the tractable-compile proxy for the EP lever; the
    # deepseek variants reuse the same code path at 61L/256e scale.
    ("qwen2-moe-a2.7b", "train_4k", "baseline", {}),
    ("qwen2-moe-a2.7b", "train_4k", "ep", {"ep": True}),
    ("qwen2-moe-a2.7b", "train_4k", "act_dp",
     {"act_spec": ("data", None, None)}),
    ("deepseek-v3-671b", "train_4k", "baseline", {}),
    ("deepseek-v3-671b", "train_4k", "ep", {"ep": True}),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()

    from ..launch.dryrun import run_cell

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    for arch, shape, name, variant in PLAN:
        key = f"{arch}|{shape}|{name}"
        if args.only and args.only not in key:
            continue
        if results.get(key, {}).get("status") == "ok":
            print(f"[skip] {key}")
            continue
        print(f"[perf] {key} ...", flush=True)
        t0 = time.time()
        try:
            res = run_cell(arch, shape, multi_pod=False, unroll=True,
                           variant=variant)
            res["variant"] = name
            r = res["roofline"]
            print(f"  ok in {time.time()-t0:.0f}s compute={r['t_compute']*1e3:.2f}ms "
                  f"coll={r['t_collective']*1e3:.1f}ms "
                  f"args={res['memory']['argument_bytes']/2**30:.2f}GB", flush=True)
        except Exception as e:  # noqa: BLE001
            res = {"status": "error", "variant": name,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-1500:]}
            print(f"  ERROR {e}", flush=True)
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
