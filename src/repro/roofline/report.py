"""EXPERIMENTS.md table generation from results/dryrun.json.

Recomputes analytic MODEL_FLOPS uniformly (analysis.model_flops) so the
useful-FLOPs ratio stays comparable even for cells produced before
refinements to the analytic model.

  PYTHONPATH=src python -m repro.roofline.report [results/dryrun.json]
"""
from __future__ import annotations

import json
import sys

from ..configs.base import SHAPES, get_arch
from . import analysis as RA


def load(path: str = "results/dryrun.json") -> dict:
    with open(path) as f:
        return json.load(f)


def roofline_rows(results: dict, mesh: str = "16x16") -> list:
    rows = []
    for key, res in sorted(results.items()):
        if res.get("status") != "ok" or res.get("mesh") != mesh:
            continue
        cfg = get_arch(res["arch"])
        shape = SHAPES[res["shape"]]
        r = res["roofline"]
        n_dev = res["n_devices"]
        mflops = RA.model_flops(cfg, shape, res["n_params"], n_dev)
        # Adjusted compute: the blocked-scan flash attention (and chunked
        # SSM scans) are costed once by XLA cost analysis; add the analytic
        # attention/state FLOPs they actually perform.
        attn = RA.attn_model_flops(cfg, shape, n_dev)
        flops_adj = r["flops"] + attn
        coll_bytes = r["coll_bytes"]
        est = False
        if res["arch"] == "deepseek-v3-671b" and not res.get("unrolled"):
            # scan-lowered cell (unrolled 61L SPMD partitioning exceeded the
            # CPU container's compile budget): while bodies are costed once,
            # so scale per-layer FLOPs/collectives by the mean scanned-
            # segment depth and mark the row estimated.
            from ..models.transformer import build_plan
            scans = [s.n for s in build_plan(cfg) if s.kind == "scan"]
            factor = sum(scans) / max(len(scans), 1)
            flops_adj = r["flops"] * factor + attn
            coll_bytes = r["coll_bytes"] * factor
            est = True
        mem_an = RA.analytic_memory_bytes(
            cfg, shape, res["memory"]["argument_bytes"],
            res["memory"]["output_bytes"], n_dev)
        t_c = flops_adj / RA.PEAK_FLOPS
        t_m = mem_an / RA.HBM_BW
        t_x = coll_bytes / RA.ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        bottleneck = max(terms, key=terms.get)
        roof_t = max(terms.values())
        rows.append({
            "arch": res["arch"] + (" †" if est else ""),
            "shape": res["shape"], "kind": res["kind"],
            "t_compute_ms": t_c * 1e3,
            "t_memory_ms": t_m * 1e3,
            "t_collective_ms": t_x * 1e3,
            "t_memory_hlo_ms": r["hbm_bytes"] / RA.HBM_BW * 1e3,
            "bottleneck": bottleneck,
            "useful": mflops / flops_adj if flops_adj else 0.0,
            "mfu_bound": (mflops / RA.PEAK_FLOPS) / roof_t if roof_t else 0.0,
            "peak_gb": res["memory"]["peak_bytes"] / 2**30,
            "arg_gb": res["memory"]["argument_bytes"] / 2**30,
            "compile_s": res.get("compile_s", 0),
            "coll_detail": r.get("coll_detail", {}),
            "coll_counts": r.get("coll_counts", {}),
            "model_flops": mflops, "flops_adj": flops_adj,
        })
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | compute | memory | collective | bottleneck | "
           "useful | MFU-bound | args GB/dev | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} ms "
            f"| {r['t_memory_ms']:.1f} ms | {r['t_collective_ms']:.1f} ms "
            f"| **{r['bottleneck']}** | {r['useful']:.2f} "
            f"| {r['mfu_bound']*100:.1f}% | {r['arg_gb']:.2f} "
            f"| {r['peak_gb']:.2f} |\n")
    return "".join(out)


def dryrun_summary(results: dict) -> str:
    by_mesh = {}
    for key, res in results.items():
        by_mesh.setdefault(res.get("mesh", "?"), []).append(res)
    lines = []
    for mesh, cells in sorted(by_mesh.items()):
        ok = [c for c in cells if c.get("status") == "ok"]
        err = [c for c in cells if c.get("status") != "ok"]
        lines.append(f"* mesh **{mesh}**: {len(ok)}/{len(cells)} cells "
                     f"lower+compile OK")
        for c in err:
            lines.append(f"    * FAIL {c['arch']}|{c['shape']}: {c.get('error')}")
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    results = load(path)
    print(dryrun_summary(results))
    print()
    rows = roofline_rows(results)
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
