"""Continuous-batching inference engine, scheduled by UFS in live mode.

The engine owns a fixed request-slot pool inside one batched model cache and
emits bounded *work items* to the scheduler:

* a **decode job** (time-sensitive tier): one chunk = one batched decode
  step over all active requests -- short device burst, then back to the
  queue (the CPU-bursty analogue);
* **prefill jobs** per admitted request (tier configurable: interactive
  prefill is time-sensitive, bulk/batch ingestion is background);
* the trainer's microbatch jobs (background tier) contend for the same
  slots -- the mixed workload of the paper, on real JAX work.

Requests carry ``tier``/``weight`` annotations -- the client-facing analogue
of the paper's ``SET task_tier/task_weight`` SQL interface.

Locking discipline (one lock, one rule): ``self._lock`` guards **all**
mutable engine state -- ``pending``, ``active``, ``lengths``, ``completed``
and every read-modify-write of the pooled ``caches`` pytree.  The decode
step and the admit path hold it for their whole read->compute->write cycle
(a batched decode replaces every cache row, so a concurrent slot write
would be lost otherwise); bulk prefill computes its batch-1 cache *outside*
the lock (it reads only immutable params and the request's own prompt) and
takes the lock only to merge the result into the pool.  ``CacheSlotPool``
has its own hint-instrumented ``LiveLock`` and is never held while waiting
on ``self._lock``, so lock order is acyclic.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.live import LiveJob, LiveKernel
from ..core.task import Tier
from .kv_cache import CacheSlotPool

_req_ids = itertools.count(1)


@dataclass
class Request:
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    tier: str = "time-sensitive"        # SET task_tier analogue
    weight: float = 10_000.0            # SET task_weight analogue
    deadline_s: Optional[float] = None  # fail if not finished within this
    rid: int = field(default_factory=lambda: next(_req_ids))
    submitted: float = 0.0
    first_token: Optional[float] = None
    finished: Optional[float] = None
    tokens: list = field(default_factory=list)
    slot: Optional[int] = None
    error: Optional[str] = None         # "deadline" / "shutdown" when failed
    done_event: threading.Event = field(default_factory=threading.Event)

    @property
    def latency(self) -> Optional[float]:
        return None if self.finished is None else self.finished - self.submitted

    @property
    def ok(self) -> bool:
        return self.finished is not None and self.error is None


class InferenceEngine:
    def __init__(self, model, params, kernel: LiveKernel, *,
                 max_batch: int = 8, max_len: int = 256,
                 group_name: str = "serve"):
        self.model = model
        self.params = params
        self.kernel = kernel
        self.max_batch = max_batch
        self.max_len = max_len
        self.group = kernel.create_group(group_name, Tier.TIME_SENSITIVE, 10_000.0)
        # Bulk-ingestion prefill runs in the background tier: the paper's
        # core idea applied inside serving -- long prefills use only slack
        # and are never dispatched ahead of interactive decode steps.
        self.bulk_group = kernel.create_group(group_name + "-bulk",
                                              Tier.BACKGROUND, 100.0)
        self.pool = CacheSlotPool(kernel, max_batch)
        self.caches = model.init_cache(max_batch, max_len)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.active: dict[int, Request] = {}     # slot -> request
        self.pending: deque = deque()    # FIFO admission; popleft is O(1)
        self._lock = threading.Lock()
        self.completed: list = []
        self._decode = jax.jit(model.decode_step)
        self._job = LiveJob(self.group, self._decode_chunk, name="decode-loop",
                            kind="bursty")
        self._running = False

    # ----------------------------------------------------------------- API
    def start(self) -> None:
        self._running = True
        self.kernel.wake(self._job)

    def stop(self, drain: bool = True) -> None:
        """Graceful shutdown.  With ``drain`` (default) whatever is still
        in flight is *failed now*: never-admitted pending requests and
        mid-decode active requests get ``error="shutdown"`` and their
        ``done_event`` set, and active cache slots go back to the pool.
        With ``drain=False`` the loop finishes the in-flight batch first.
        Either way the blocked decode loop is woken so it observes the
        shutdown and exits instead of sleeping forever."""
        with self._lock:
            self._running = False
            if drain:
                while self.pending:
                    self._fail_locked(self.pending.popleft(), "shutdown")
                for slot in list(self.active):
                    self._fail_locked(self.active[slot], "shutdown", slot=slot)
        # Wake the (possibly parked) decode loop so it observes the
        # shutdown.  A chunk that already decided "blocked" may not have
        # parked yet, and waking a running job would double-dispatch it,
        # so wait for the job to settle before waking -- bounded, not
        # best-effort: a parked loop never wakes itself.
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            state = self._job.state.value
            if state == "blocked":
                self.kernel.wake(self._job)
                return
            if state in ("exited", "new"):
                return                       # already done / never started
            time.sleep(0.001)                # running/runnable: let it land

    def _fail_locked(self, req: Request, error: str,
                     slot: Optional[int] = None) -> None:
        """Fail a request (deadline / shutdown): mark it, wake its waiter,
        and release its cache slot.  Caller holds ``self._lock``."""
        req.error = error
        req.finished = time.monotonic()
        if slot is not None:
            self.active.pop(slot, None)
            self.lengths[slot] = 0
            self.pool.release(self._job, slot)
        self.completed.append(req)
        req.done_event.set()

    def _expire_locked(self, now: float) -> None:
        """Fail every request whose deadline has passed: pending requests
        before they occupy a slot, active ones releasing theirs.  Caller
        holds ``self._lock``."""
        expired = [r for r in self.pending
                   if r.deadline_s is not None
                   and now - r.submitted > r.deadline_s]
        for req in expired:
            self.pending.remove(req)
            self._fail_locked(req, "deadline")
        for slot, req in list(self.active.items()):
            if (req.deadline_s is not None
                    and now - req.submitted > req.deadline_s):
                self._fail_locked(req, "deadline", slot=slot)

    def submit(self, req: Request) -> Request:
        req.submitted = time.monotonic()
        if req.tier == "background":
            # bulk request: its prefill is a background job; once prefilled
            # the request joins the (time-sensitive) decode batch.
            job = LiveJob(self.bulk_group,
                          lambda budget, r=req: self._bulk_prefill_chunk(r),
                          name=f"bulk-prefill-{req.rid}", kind="bound")
            self.kernel.wake(job)
            return req
        with self._lock:
            self.pending.append(req)
        if self._job.state.value == "blocked":
            self.kernel.wake(self._job)      # new work arrived: wake the loop
        return req

    def _bulk_prefill_chunk(self, req: Request) -> str:
        slot = self.pool.alloc(self._job, str(req.rid))
        if slot is None:
            return "yield"                   # no slot free yet: retry later
        # Prefill outside the engine lock: it reads only immutable state
        # (params, the request's own prompt). The slot is reserved, so no
        # other writer targets this cache row until we publish it below.
        plen = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, caches1 = self.model.prefill(self.params, batch, self.max_len)
        with self._lock:
            self.caches = _write_slot(self.caches, caches1, slot)
            self.lengths[slot] = plen
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(tok)
            req.first_token = time.monotonic()
            self.active[slot] = req
        if self._job.state.value == "blocked":
            self.kernel.wake(self._job)
        return "done"

    # ------------------------------------------------------------ mechanics
    def _admit_locked(self) -> None:
        """Admit pending requests into free cache slots (prefill inline --
        prompts are short in the demo; long prompts become chunked prefill
        jobs in examples/mixed_serving.py). Caller holds ``self._lock``."""
        while self.pending:
            req = self.pending[0]
            slot = self.pool.alloc(self._job, str(req.rid))
            if slot is None:
                return                       # pool exhausted: retry next chunk
            self.pending.popleft()
            # single-request prefill into the pooled cache at `slot`
            plen = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            logits, caches1 = self.model.prefill(self.params, batch, self.max_len)
            self.caches = _write_slot(self.caches, caches1, slot)
            self.lengths[slot] = plen
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(tok)
            req.first_token = time.monotonic()
            self.active[slot] = req

    def _decode_chunk(self, budget: float) -> str:
        """One bounded chunk: admit + one batched decode step.  Holds the
        engine lock for the whole read->decode->write cycle (the decode
        replaces every cache row, see the locking discipline above)."""
        with self._lock:
            self._expire_locked(time.monotonic())
            self._admit_locked()
            if not self.active:
                return "blocked" if self._running else "done"
            pos = int(self.lengths.max())
            toks = np.zeros((self.max_batch, 1), np.int32)
            for slot, req in self.active.items():
                toks[slot, 0] = req.tokens[-1]
            logits, self.caches = self._decode(self.params, self.caches,
                                               jnp.asarray(toks), pos)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            now = time.monotonic()
            finished = []
            for slot, req in list(self.active.items()):
                req.tokens.append(int(nxt[slot]))
                self.lengths[slot] += 1
                if len(req.tokens) >= req.max_new_tokens or self.lengths[slot] >= self.max_len - 1:
                    req.finished = now
                    finished.append(slot)
            for slot in finished:
                req = self.active.pop(slot)
                self.completed.append(req)
                req.done_event.set()
                self.pool.release(self._job, slot)
                self.lengths[slot] = 0
            return "yield" if (self.active or self.pending or self._running) else "done"


def _write_slot(pool_caches, single_caches, slot: int):
    """Copy a batch-1 cache pytree into row ``slot`` of the pooled caches.
    The batch dim is the first dim where the single cache has size 1 and the
    pool has the pool size (layer dims of scanned segments match on both)."""
    def write(pool_leaf, one_leaf):
        for d in range(pool_leaf.ndim):
            if one_leaf.shape[d] == 1 and pool_leaf.shape[d] > 1:
                idx = [slice(None)] * pool_leaf.ndim
                idx[d] = slice(slot, slot + 1)
                return pool_leaf.at[tuple(idx)].set(one_leaf.astype(pool_leaf.dtype))
        return pool_leaf
    return jax.tree.map(write, pool_caches, single_caches)
