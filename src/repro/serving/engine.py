"""Continuous-batching inference engine, scheduled by UFS in live mode.

The engine owns a fixed request-slot pool inside one batched model cache and
emits bounded *work items* to the scheduler:

* a **decode job** (time-sensitive tier): one chunk = one batched decode
  step over all active requests -- short device burst, then back to the
  queue (the CPU-bursty analogue);
* **prefill jobs** per admitted request (tier configurable: interactive
  prefill is time-sensitive, bulk/batch ingestion is background);
* the trainer's microbatch jobs (background tier) contend for the same
  slots -- the mixed workload of the paper, on real JAX work.

Requests carry ``tier``/``weight`` annotations -- the client-facing analogue
of the paper's ``SET task_tier/task_weight`` SQL interface.

Locking discipline (DESIGN.md section 13): ``self._lock`` guards **all**
mutable engine state -- ``pending``, ``active``, ``lengths``, ``completed``,
``_inflight_bulk``, the generation counter and the pooled ``caches``
reference -- but on the hot path it is *never held across device compute*:

* **decode** snapshots ``(gen, caches, toks, pos)`` under the lock, runs the
  jitted step and the host sync outside it, and merges the result back under
  the lock only if the generation counter is unchanged (a concurrent
  admission or bulk merge published new cache rows the snapshot lacks, so
  the stale step is discarded and retried);
* **admission** reserves slots under the lock (pool alloc + pending pop),
  prefills all admitted prompts in one padded batched call outside it, and
  publishes the rows with one jitted scatter (``write_slots``) under it;
* **bulk prefill** computes its batch-1 cache outside the lock and takes it
  only to merge.

Every publish of new cache *rows* bumps ``self._gen``; row removals
(expire/finish) do not -- decode rows are independent, so clobbering a freed
row is harmless, while decoding against a snapshot that lacks a newly
admitted row would lose that request's first step.  ``CacheSlotPool``'s
LiveLock is only ever acquired while holding (or without) ``self._lock``,
never the reverse, so lock order stays acyclic.

``overlap_decode=False`` / ``batched_admission=False`` preserve the
pre-overhaul behavior (lock held across compute, per-request prefill inside
the admission loop); ``benchmarks/serving_bench.py`` uses them as its
recorded baseline.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.live import LiveJob, LiveKernel
from ..core.task import Tier
from .kv_cache import CacheSlotPool, cache_batch_axes, make_write_slots

_req_ids = itertools.count(1)


@dataclass
class Request:
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    tier: str = "time-sensitive"        # SET task_tier analogue
    weight: float = 10_000.0            # SET task_weight analogue
    deadline_s: Optional[float] = None  # fail if not finished within this
    rid: int = field(default_factory=lambda: next(_req_ids))
    submitted: float = 0.0
    first_token: Optional[float] = None
    finished: Optional[float] = None
    tokens: list = field(default_factory=list)
    token_times: list = field(default_factory=list)  # monotonic per token
    slot: Optional[int] = None
    error: Optional[str] = None         # "deadline" / "shutdown" when failed
    done_event: threading.Event = field(default_factory=threading.Event)

    @property
    def latency(self) -> Optional[float]:
        return None if self.finished is None else self.finished - self.submitted

    @property
    def ok(self) -> bool:
        return self.finished is not None and self.error is None


@dataclass
class EngineStats:
    """Hot-path engine counters, deliberately *outside* ``Metrics`` so the
    scheduler's ``Metrics.summary()`` (and the sim benchmark's
    ``summary_sha256``) is untouched by serving instrumentation."""
    decode_steps: int = 0
    decode_invalidations: int = 0       # stale snapshots discarded (gen raced)
    batched_admissions: int = 0         # padded multi-request prefill calls
    admitted: int = 0                   # requests activated via admission
    bulk_prefills: int = 0              # background prefills merged
    lock_hold_s: deque = field(default_factory=lambda: deque(maxlen=65536))

    def summary(self) -> dict:
        holds = sorted(self.lock_hold_s)

        def pct(p):
            if not holds:
                return 0.0
            return holds[min(len(holds) - 1, int(p * (len(holds) - 1)))]

        return {
            "decode_steps": self.decode_steps,
            "decode_invalidations": self.decode_invalidations,
            "batched_admissions": self.batched_admissions,
            "admitted": self.admitted,
            "bulk_prefills": self.bulk_prefills,
            "lock_hold_p50_us": pct(0.50) * 1e6,
            "lock_hold_p99_us": pct(0.99) * 1e6,
            "lock_hold_max_us": (holds[-1] if holds else 0.0) * 1e6,
            "lock_holds": len(holds),
        }


def _next_pow2(n: int) -> int:
    return 1 << max(3, (n - 1).bit_length())      # floor bucket at 8


class InferenceEngine:
    def __init__(self, model, params, kernel: LiveKernel, *,
                 max_batch: int = 8, max_len: int = 256,
                 group_name: str = "serve",
                 overlap_decode: bool = True,
                 batched_admission: bool = True):
        self.model = model
        self.params = params
        self.kernel = kernel
        self.max_batch = max_batch
        self.max_len = max_len
        self.overlap_decode = overlap_decode
        self.batched_admission = batched_admission
        self.group = kernel.create_group(group_name, Tier.TIME_SENSITIVE, 10_000.0)
        # Bulk-ingestion prefill runs in the background tier: the paper's
        # core idea applied inside serving -- long prefills use only slack
        # and are never dispatched ahead of interactive decode steps.
        self.bulk_group = kernel.create_group(group_name + "-bulk",
                                              Tier.BACKGROUND, 100.0)
        self.pool = CacheSlotPool(kernel, max_batch)
        self.caches = model.init_cache(max_batch, max_len)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.active: dict[int, Request] = {}     # slot -> request
        self.pending: deque = deque()    # FIFO admission; popleft is O(1)
        self._inflight_bulk: dict[int, Request] = {}  # rid -> bulk req pre-slot
        self._lock = threading.Lock()
        self.completed: list = []
        self.stats = EngineStats()
        self._gen = 0                    # bumped on every cache-row publish
        self._decode = jax.jit(model.decode_step)
        # Batched ragged admission prefill: one padded call for all admits.
        # Optional -- models without prefill_batch fall back per-request.
        fn = getattr(model, "prefill_batch", None)
        self._prefill_batch_fn = (jax.jit(fn, static_argnums=(2,))
                                  if fn is not None else None)
        # One jitted scatter publishes any number of cache rows at once;
        # the batch-axis map is probed shape-only (no device memory).
        self._batch_axes = cache_batch_axes(model, max_len)
        self._write_slots = make_write_slots(self._batch_axes)
        self._job = LiveJob(self.group, self._decode_chunk, name="decode-loop",
                            kind="bursty")
        self._running = False
        self._nudge_armed = False
        # Bulk prefill jobs parked on slot exhaustion (FIFO), and wakes
        # queued under the lock to be delivered after it is dropped.
        self._slot_waiters: deque = deque()
        self._slot_wakes: list = []

    # ----------------------------------------------------------------- API
    def start(self) -> None:
        self._running = True
        self.kernel.wake(self._job)

    def stop(self, drain: bool = True) -> None:
        """Graceful shutdown.  With ``drain`` (default) whatever is still
        in flight is *failed now*: never-admitted pending requests,
        mid-decode active requests and not-yet-landed bulk submissions get
        ``error="shutdown"`` and their ``done_event`` set, and active cache
        slots go back to the pool.  (A bulk request whose prefill already
        reserved a slot releases it itself when its merge step observes the
        error.)  With ``drain=False`` the loop finishes the in-flight batch
        first.  Either way the blocked decode loop is woken so it observes
        the shutdown and exits instead of sleeping forever."""
        with self._lock:
            self._running = False
            if drain:
                while self.pending:
                    self._fail_locked(self.pending.popleft(), "shutdown")
                for slot in list(self.active):
                    self._fail_locked(self.active[slot], "shutdown", slot=slot)
                for req in list(self._inflight_bulk.values()):
                    self._fail_locked(req, "shutdown")
            # Bulk prefill jobs parked on slot exhaustion must be woken to
            # observe the shutdown (their chunks fail the request and
            # exit); otherwise they would sleep forever.
            while self._slot_waiters:
                self._slot_wakes.append(self._slot_waiters.popleft())
        self._flush_slot_wakes()
        # Wake the (possibly parked) decode loop so it observes the
        # shutdown.  A chunk that already decided "blocked" may not have
        # parked yet, and waking a running job would double-dispatch it, so
        # wait for the job-state to settle before waking.  The executor's
        # event-driven settle wait replaces the old 1 ms busy-poll; the
        # bounded poll remains as a fallback for executors without it.
        settle = getattr(self.kernel.executor, "wait_job_settle", None)
        if settle is not None:
            state = settle(self._job, states=("blocked", "exited", "new"),
                           timeout=2.0)
            if state == "blocked":
                self.kernel.wake(self._job)
            return
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            state = self._job.state.value
            if state == "blocked":
                self.kernel.wake(self._job)
                return
            if state in ("exited", "new"):
                return                       # already done / never started
            time.sleep(0.001)                # running/runnable: let it land

    def _fail_locked(self, req: Request, error: str,
                     slot: Optional[int] = None) -> None:
        """Fail a request (deadline / shutdown): mark it, wake its waiter,
        and release its cache slot.  Caller holds ``self._lock``."""
        req.error = error
        req.finished = time.monotonic()
        self._inflight_bulk.pop(req.rid, None)
        if slot is not None:
            self.active.pop(slot, None)
            self.lengths[slot] = 0
            self.pool.release(self._job, slot)
            self._notify_slot_free_locked()
        self.completed.append(req)
        req.done_event.set()

    def _expire_locked(self, now: float) -> None:
        """Fail every request whose deadline has passed: pending and
        in-flight bulk requests before they occupy a slot, active ones
        releasing theirs.  Caller holds ``self._lock``."""
        expired = [r for r in self.pending
                   if r.deadline_s is not None
                   and now - r.submitted > r.deadline_s]
        for req in expired:
            self.pending.remove(req)
            self._fail_locked(req, "deadline")
        for req in list(self._inflight_bulk.values()):
            if (req.deadline_s is not None
                    and now - req.submitted > req.deadline_s):
                self._fail_locked(req, "deadline")
        for slot, req in list(self.active.items()):
            if (req.deadline_s is not None
                    and now - req.submitted > req.deadline_s):
                self._fail_locked(req, "deadline", slot=slot)

    def submit(self, req: Request) -> Request:
        req.submitted = time.monotonic()
        if req.tier == "background":
            # bulk request: its prefill is a background job; once prefilled
            # the request joins the (time-sensitive) decode batch.  Tracked
            # in _inflight_bulk until it lands a slot so stop(drain=True)
            # and deadline expiry can fail it (it used to be invisible:
            # its done_event waiter hung until deadline).
            with self._lock:
                self._inflight_bulk[req.rid] = req
            holder: list = []
            job = LiveJob(self.bulk_group,
                          lambda budget, r=req: self._bulk_prefill_chunk(
                              r, holder[0]),
                          name=f"bulk-prefill-{req.rid}", kind="bound")
            holder.append(job)
            self.kernel.wake(job)
            return req
        with self._lock:
            self.pending.append(req)
            # The loop may be publishing BLOCKED right now without having
            # seen this request (state reads "running" for a moment after
            # the chunk's block decision).  Only possible when the engine
            # looks idle; a deferred nudge re-checks and self-heals.  At
            # most one nudge chain is armed at a time -- defer() spawns a
            # timer thread, so arming per-submit would storm the hot path.
            arm = (not self.active and not self._nudge_armed)
            if arm:
                self._nudge_armed = True
        if self._job.state.value == "blocked":
            if arm:                          # wake supersedes the nudge:
                with self._lock:             # don't leak the armed flag
                    self._nudge_armed = False
            self.kernel.wake(self._job)      # new work arrived: wake the loop
        elif arm:
            self.kernel.executor.defer(0.002, self._nudge_decode_loop)
        return req

    def _nudge_decode_loop(self, delay: float = 0.002) -> None:
        """Self-healing wake for the submit/park race: retries with backoff
        while pending work is stranded; never wakes a non-blocked job (that
        would double-dispatch it)."""
        with self._lock:
            if not (self.pending and self._running):
                self._nudge_armed = False    # under _lock: arm/clear race-free
                return
        if self._job.state.value == "blocked":
            with self._lock:
                self._nudge_armed = False
            self.kernel.wake(self._job)
            return
        nxt = min(delay * 1.5, 0.05)
        self.kernel.executor.defer(nxt, lambda: self._nudge_decode_loop(nxt))

    # --------------------------------------------- slot-exhaustion parking
    def _notify_slot_free_locked(self) -> None:
        """A cache slot went back to the pool: queue a wake for one parked
        bulk-prefill waiter.  Caller holds ``self._lock``; the wake itself
        is delivered by :meth:`_flush_slot_wakes` after the lock drops
        (kernel calls are never made under the engine lock)."""
        if self._slot_waiters:
            self._slot_wakes.append(self._slot_waiters.popleft())

    def _flush_slot_wakes(self) -> None:
        with self._lock:
            if not self._slot_wakes:
                return
            wakes, self._slot_wakes = self._slot_wakes, []
        for job in wakes:
            self._wake_when_settled(job)

    def _wake_when_settled(self, job, delay: float = 0.001) -> None:
        """Wake a bulk-prefill job parked on slot exhaustion.  Normally it
        settled into BLOCKED long ago; if the wake races the job's own
        epilogue (state reads running/runnable for a moment after its chunk
        returned "blocked"), retry on a deferred timer -- waking a
        non-blocked job would double-dispatch it."""
        state = job.state.value
        if state == "blocked":
            self.kernel.wake(job)
        elif state != "exited":
            nxt = min(delay * 2, 0.05)
            self.kernel.executor.defer(
                nxt, lambda: self._wake_when_settled(job, nxt))

    # ------------------------------------------------------------ internals
    @contextmanager
    def _held(self):
        """Engine lock + hold-time sample (acquire-to-release, so the
        benchmark's decode-lock hold stat reflects actual exclusion, not
        wait time)."""
        with self._lock:
            t0 = time.perf_counter()
            try:
                yield
            finally:
                self.stats.lock_hold_s.append(time.perf_counter() - t0)

    def _bulk_prefill_chunk(self, req: Request, job) -> str:
        try:
            return self._bulk_prefill_impl(req, job)
        finally:
            self._flush_slot_wakes()

    def _bulk_prefill_impl(self, req: Request, job) -> str:
        with self._lock:
            if req.error is not None or not self._running:
                # Failed (drain/deadline) or shutting down: deregister any
                # stale waiter entry so a future release is not wasted on a
                # job that will immediately exit.
                try:
                    self._slot_waiters.remove(job)
                except ValueError:
                    pass
                if req.error is None:
                    self._fail_locked(req, "shutdown")
                return "done"
            # Register as a slot waiter *before* trying to allocate: a
            # release racing this chunk can then never slip between a
            # failed alloc and the registration (that wake would be lost
            # and the job stranded).  Spurious wakes are harmless -- the
            # chunk just retries -- lost ones are not.
            if job not in self._slot_waiters:
                self._slot_waiters.append(job)
        slot = self.pool.alloc(job, str(req.rid))
        if slot is None:
            # Slot-exhausted: park until a release hands us the slot.
            # (The old path returned "yield" here; under load that
            # yield-spin of every queued bulk job starved the decode loop
            # that would have freed the slots -- a livelock.)
            return "blocked"
        with self._lock:
            try:
                self._slot_waiters.remove(job)
                consumed = False
            except ValueError:
                consumed = True  # a release notification popped us already
            if consumed:
                # We got a slot by allocation AND swallowed a wake meant
                # for a waiter: pass the signal on so it is not lost.
                self._notify_slot_free_locked()
        # Prefill outside the engine lock: it reads only immutable state
        # (params, the request's own prompt). The slot is reserved, so no
        # other writer targets this cache row until we publish it below.
        plen = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, caches1 = self.model.prefill(self.params, batch, self.max_len)
        tok = int(np.asarray(jnp.argmax(logits[0, -1])))  # sync outside lock
        wake = False
        with self._held():
            now = time.monotonic()
            if req.error is not None or not self._running:
                # Failed while we were prefilling (drain or deadline):
                # hand the reserved slot back and do not activate.
                self.pool.release(self._job, slot)
                self._notify_slot_free_locked()
                if req.error is None:
                    self._fail_locked(req, "shutdown")
            else:
                if self.overlap_decode:
                    self.caches = self._write_slots(
                        self.caches, caches1,
                        jnp.asarray([slot], jnp.int32))
                else:
                    self.caches = _write_slot(self.caches, caches1, slot)
                self._gen += 1           # new row published: stale decode
                self.lengths[slot] = plen
                req.tokens.append(tok)
                req.first_token = now
                req.token_times.append(now)
                self.active[slot] = req
                self._inflight_bulk.pop(req.rid, None)
                self.stats.bulk_prefills += 1
                wake = True
        if wake and self._job.state.value == "blocked":
            self.kernel.wake(self._job)
        return "done"

    # ----------------------------------------------------------- admission
    def _reserve_admissions_locked(self) -> list:
        """Pop admissible pending requests and reserve a pool slot for
        each; their prefill runs outside the lock.  Caller holds it."""
        admits = []
        while self.pending:
            slot = self.pool.alloc(self._job, str(self.pending[0].rid))
            if slot is None:
                break                        # pool exhausted: retry next chunk
            req = self.pending.popleft()
            req.slot = slot
            admits.append((req, slot))
        return admits

    def _prefill_admissions(self, admits: list) -> None:
        """Prefill + activate a batch of reserved admissions.  Compute runs
        outside the lock; activation re-checks ``_running`` under it (a
        drain between reservation and merge must fail the requests and
        return their slots, or they would be invisible to shutdown)."""
        if self.batched_admission and self._prefill_batch_fn is not None:
            self._prefill_admissions_batched(admits)
            return
        for req, slot in admits:
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            logits, rows = self.model.prefill(self.params, batch, self.max_len)
            tok = int(np.asarray(jnp.argmax(logits[0, -1])))
            with self._held():
                if not self._running:
                    self._fail_locked(req, "shutdown", slot=slot)
                    continue
                if self.overlap_decode:
                    self.caches = self._write_slots(
                        self.caches, rows, jnp.asarray([slot], jnp.int32))
                else:
                    self.caches = _write_slot(self.caches, rows, slot)
                self._gen += 1
                self._activate_locked(req, slot, tok, time.monotonic())

    def _prefill_admissions_batched(self, admits: list) -> None:
        """One padded prefill for all admitted prompts: rows are padded to
        ``max_batch`` and prompt length to a power-of-two bucket, so the
        jitted call retraces once per length bucket, not per batch shape.
        Padding rows carry slot index ``max_batch`` -- out of range, so the
        publish scatter drops them (``mode="drop"``; -1 would wrap)."""
        L = _next_pow2(max(len(r.prompt) for r, _ in admits))
        toks = np.zeros((self.max_batch, L), np.int32)
        lengths = np.ones((self.max_batch,), np.int32)
        slots = np.full((self.max_batch,), self.max_batch, np.int32)
        for i, (req, slot) in enumerate(admits):
            plen = len(req.prompt)
            toks[i, :plen] = req.prompt
            lengths[i] = plen
            slots[i] = slot
        batch = {"tokens": jnp.asarray(toks),
                 "lengths": jnp.asarray(lengths)}
        logits, rows = self._prefill_batch_fn(self.params, batch, self.max_len)
        first = np.asarray(jnp.argmax(logits[:, 0], axis=-1))  # host sync
        self.stats.batched_admissions += 1
        failed = []
        with self._held():
            if not self._running:
                failed = admits
            else:
                self.caches = self._write_slots(self.caches, rows,
                                                jnp.asarray(slots))
                self._gen += 1
                now = time.monotonic()
                for i, (req, slot) in enumerate(admits):
                    self._activate_locked(req, slot, int(first[i]), now)
            if failed:
                for req, slot in failed:
                    self._fail_locked(req, "shutdown", slot=slot)

    def _activate_locked(self, req: Request, slot: int, tok: int,
                         now: float) -> None:
        self.lengths[slot] = len(req.prompt)
        req.tokens.append(tok)
        req.first_token = now
        req.token_times.append(now)
        self.active[slot] = req
        self.stats.admitted += 1

    # ------------------------------------------------------------ mechanics
    def _decode_chunk(self, budget: float) -> str:
        try:
            if not self.overlap_decode:
                return self._decode_chunk_legacy(budget)
            return self._decode_chunk_impl(budget)
        finally:
            # Deliver any slot-free wakes queued while the lock was held
            # (finish / expiry released slots with bulk waiters parked).
            self._flush_slot_wakes()

    def _decode_chunk_impl(self, budget: float) -> str:
        # --- phase 1 (locked): expire + reserve admissions ---------------
        with self._held():
            self._expire_locked(time.monotonic())
            admits = self._reserve_admissions_locked() if self._running else []
        # --- phase 2 (unlocked): batched admission prefill ---------------
        if admits:
            self._prefill_admissions(admits)
        # --- phase 3 (locked): snapshot --------------------------------
        with self._held():
            if not self.active:
                if self._running and self.pending and self.pool.free:
                    # An arrival landed between admission (phase 1) and
                    # here while slots are free: retry immediately instead
                    # of parking over runnable work.  (Without free slots
                    # the pending work waits on a bulk merge, which wakes
                    # the loop itself -- yielding would just spin.)
                    return "yield"
                return "blocked" if self._running else "done"
            gen = self._gen
            caches = self.caches
            pos = int(self.lengths.max())
            toks = np.zeros((self.max_batch, 1), np.int32)
            snap_slots = []
            for slot, req in self.active.items():
                toks[slot, 0] = req.tokens[-1]
                snap_slots.append(slot)
        # --- phase 4 (unlocked): device decode + host sync ---------------
        logits, new_caches = self._decode(self.params, caches,
                                          jnp.asarray(toks), pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        # --- phase 5 (locked): merge or discard --------------------------
        with self._held():
            if self._gen != gen:
                # A concurrent admission/bulk merge published rows this
                # snapshot lacks; committing would lose their prefill
                # state.  Discard and retry -- per-row results for
                # still-active slots are recomputed next chunk.
                self.stats.decode_invalidations += 1
                return "yield"
            self.caches = new_caches
            self.stats.decode_steps += 1
            now = time.monotonic()
            finished = []
            for slot in snap_slots:
                req = self.active.get(slot)
                if req is None:
                    continue             # finished/expired mid-step: row is
                                         # free, clobbering it was harmless
                req.tokens.append(int(nxt[slot]))
                req.token_times.append(now)
                self.lengths[slot] += 1
                if (len(req.tokens) >= req.max_new_tokens
                        or self.lengths[slot] >= self.max_len - 1):
                    req.finished = now
                    finished.append(slot)
            for slot in finished:
                req = self.active.pop(slot)
                self.completed.append(req)
                req.done_event.set()
                self.pool.release(self._job, slot)
                self._notify_slot_free_locked()
                self.lengths[slot] = 0
            return ("yield" if (self.active or self.pending or self._running)
                    else "done")

    def _decode_chunk_legacy(self, budget: float) -> str:
        """Pre-overhaul chunk: admit + one batched decode step with the
        engine lock held for the whole read->decode->write cycle.  Kept as
        the serving benchmark's recorded baseline (``overlap_decode=False``)."""
        with self._held():
            self._expire_locked(time.monotonic())
            self._admit_locked()
            if not self.active:
                return "blocked" if self._running else "done"
            pos = int(self.lengths.max())
            toks = np.zeros((self.max_batch, 1), np.int32)
            for slot, req in self.active.items():
                toks[slot, 0] = req.tokens[-1]
            logits, self.caches = self._decode(self.params, self.caches,
                                               jnp.asarray(toks), pos)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            self.stats.decode_steps += 1
            now = time.monotonic()
            finished = []
            for slot, req in list(self.active.items()):
                req.tokens.append(int(nxt[slot]))
                req.token_times.append(now)
                self.lengths[slot] += 1
                if len(req.tokens) >= req.max_new_tokens or self.lengths[slot] >= self.max_len - 1:
                    req.finished = now
                    finished.append(slot)
            for slot in finished:
                req = self.active.pop(slot)
                self.completed.append(req)
                req.done_event.set()
                self.pool.release(self._job, slot)
                self._notify_slot_free_locked()
                self.lengths[slot] = 0
            return "yield" if (self.active or self.pending or self._running) else "done"

    def _admit_locked(self) -> None:
        """Legacy admission: prefill per-request *inside* the engine lock
        (prompts are short in the demo; long prompts become chunked prefill
        jobs in examples/mixed_serving.py). Caller holds ``self._lock``."""
        while self.pending:
            req = self.pending[0]
            slot = self.pool.alloc(self._job, str(req.rid))
            if slot is None:
                return                       # pool exhausted: retry next chunk
            self.pending.popleft()
            # single-request prefill into the pooled cache at `slot`
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            logits, caches1 = self.model.prefill(self.params, batch, self.max_len)
            self.caches = _write_slot(self.caches, caches1, slot)
            tok = int(jnp.argmax(logits[0, -1]))
            self._activate_locked(req, slot, tok, time.monotonic())


def _write_slot(pool_caches, single_caches, slot: int):
    """Copy a batch-1 cache pytree into row ``slot`` of the pooled caches.
    The batch dim is the first dim where the single cache has size 1 and the
    pool has the pool size (layer dims of scanned segments match on both).
    Legacy path -- the hot path uses the jitted ``make_write_slots`` scatter."""
    def write(pool_leaf, one_leaf):
        for d in range(pool_leaf.ndim):
            if one_leaf.shape[d] == 1 and pool_leaf.shape[d] > 1:
                idx = [slice(None)] * pool_leaf.ndim
                idx[d] = slice(slot, slot + 1)
                return pool_leaf.at[tuple(idx)].set(one_leaf.astype(pool_leaf.dtype))
        return pool_leaf
    return jax.tree.map(write, pool_caches, single_caches)
