"""Serving-side KV cache slot management.

The engine keeps a fixed pool of per-request cache slots inside the batched
model cache (batch dimension = pool size). The allocator's free-list is
guarded by a hint-instrumented LiveLock -- the engine-level analogue of the
shared-structure LWLocks the paper hints on: if a background task (bulk
prefill, compaction) holds the allocator while a time-sensitive decode
needs a slot, the scheduler boosts the holder.
"""
from __future__ import annotations

from typing import Optional

import jax

from ..core.base import SchedCore
from ..core.live import LiveLock


def cache_batch_axes(model, max_len: int):
    """Per-leaf batch-axis map for ``model.init_cache`` pytrees.

    Probes the cache shape at two batch sizes under ``jax.eval_shape`` (no
    device memory touched) and records, for every leaf, the first axis whose
    extent tracks the batch size.  Leaves with no batch axis (shared state)
    get ``-1`` -- a plain int sentinel, because ``None`` is not a pytree
    leaf and would collapse the tree structure.
    """
    a = jax.eval_shape(lambda: model.init_cache(2, max_len))
    b = jax.eval_shape(lambda: model.init_cache(3, max_len))

    def axis(x, y):
        for d, (m, n) in enumerate(zip(x.shape, y.shape)):
            if m != n:
                return d
        return -1

    return jax.tree.map(axis, a, b)


def make_write_slots(batch_axes):
    """Build a jitted ``write(pool, rows, slots) -> pool`` scatter that
    publishes a batch of per-request cache rows into the pooled cache in one
    fused device program (replacing a per-request ``tree_map`` + host loop).

    ``rows`` is a cache pytree whose batch axis indexes the rows to write
    and ``slots`` an int32 vector of destination pool rows.  Out-of-range
    slot indices (use the pool size as the padding sentinel -- *not* -1,
    which JAX would wrap to the last row) are dropped by ``mode="drop"``, so
    padded admission batches scatter only their real rows.

    The pool is *not* donated: the engine's overlapped decode keeps
    references to superseded snapshots (generation-counter discard path),
    so donation would invalidate buffers still being read.
    """
    def write(pool, rows, slots):
        def one(pool_leaf, rows_leaf, ax):
            if ax < 0:
                return pool_leaf
            idx = (slice(None),) * ax + (slots,)
            return pool_leaf.at[idx].set(rows_leaf.astype(pool_leaf.dtype),
                                         mode="drop")
        return jax.tree.map(one, pool, rows, batch_axes)

    return jax.jit(write)


class CacheSlotPool:
    def __init__(self, kernel: SchedCore, n_slots: int):
        self.n = n_slots
        self.free = list(range(n_slots))
        self.lock = LiveLock(kernel, "kv-slot-allocator")
        self.in_use: dict[int, str] = {}

    def alloc(self, job, request_id: str) -> Optional[int]:
        if not self.lock.acquire(job):
            return None
        try:
            if not self.free:
                return None
            slot = self.free.pop()
            self.in_use[slot] = request_id
            return slot
        finally:
            self.lock.release(job)

    def release(self, job, slot: int) -> None:
        if not self.lock.acquire(job):
            return
        try:
            self.in_use.pop(slot, None)
            self.free.append(slot)
        finally:
            self.lock.release(job)
