"""Serving-side KV cache slot management.

The engine keeps a fixed pool of per-request cache slots inside the batched
model cache (batch dimension = pool size). The allocator's free-list is
guarded by a hint-instrumented LiveLock -- the engine-level analogue of the
shared-structure LWLocks the paper hints on: if a background task (bulk
prefill, compaction) holds the allocator while a time-sensitive decode
needs a slot, the scheduler boosts the holder.
"""
from __future__ import annotations

from typing import Optional

from ..core.base import SchedCore
from ..core.live import LiveLock


class CacheSlotPool:
    def __init__(self, kernel: SchedCore, n_slots: int):
        self.n = n_slots
        self.free = list(range(n_slots))
        self.lock = LiveLock(kernel, "kv-slot-allocator")
        self.in_use: dict[int, str] = {}

    def alloc(self, job, request_id: str) -> Optional[int]:
        if not self.lock.acquire(job):
            return None
        try:
            if not self.free:
                return None
            slot = self.free.pop()
            self.in_use[slot] = request_id
            return slot
        finally:
            self.lock.release(job)

    def release(self, job, slot: int) -> None:
        if not self.lock.acquire(job):
            return
        try:
            self.in_use.pop(slot, None)
            self.free.append(slot)
        finally:
            self.lock.release(job)
