"""Deterministic stub model for serving-path benchmarks and tests.

A tiny recurrent model with the engine's full contract (``init_cache`` /
``prefill`` / ``prefill_batch`` / ``decode_step``): real jittable JAX
compute, but microseconds per step, so `benchmarks/serving_bench.py` can
measure *scheduler and engine* overhead (lock hold, wakeup latency,
admission batching) instead of device FLOPs.

Unlike a KV-cache transformer, the recurrent state makes batched
right-padded prefill *exactly* equivalent to per-request prefill: the
padded tail would corrupt a naive final state, so ``prefill_batch`` stacks
the per-step states and gathers each row's state at ``lengths-1``.  Engine
greedy decode through this stub is therefore bit-comparable against a
direct (unscheduled) prefill+decode loop -- the hot-path correctness oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class TinyStubModel:
    """h' = tanh(h @ Wh + embed[token]); logits = h' @ Wout."""

    def __init__(self, d_model: int = 32, vocab: int = 32, depth: int = 1,
                 seed: int = 0):
        self.d_model = d_model
        self.vocab = vocab
        self.depth = depth            # extra tanh-matmul rounds per step
        # Pre-jitted internals: the engine calls prefill/decode eagerly on
        # some paths, and an un-jitted lax.scan over a per-call closure
        # recompiles on every invocation -- hundreds of ms that would
        # swamp the scheduler overhead this stub exists to expose.
        self._jit_prefill = jax.jit(self._prefill_impl)
        self._jit_prefill_batch = jax.jit(self._prefill_batch_impl)
        self._jit_decode = jax.jit(self._decode_impl)

    def init_params(self, seed: int = 0):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        s = 1.0 / jnp.sqrt(self.d_model)
        return {
            "emb": jax.random.normal(k1, (self.vocab, self.d_model)) * s,
            "wh": jax.random.normal(k2, (self.d_model, self.d_model)) * s,
            "wout": jax.random.normal(k3, (self.d_model, self.vocab)) * s,
        }

    # ------------------------------------------------------------- contract
    def init_cache(self, batch_size: int, smax: int, dtype=None):
        del smax
        dt = jnp.dtype(dtype or jnp.float32)
        return {"h": jnp.zeros((batch_size, self.d_model), dt)}

    def _step(self, params, h, tok):
        """One recurrent update; tok: (B,) int32, h: (B, D)."""
        h = jnp.tanh(h @ params["wh"] + params["emb"][tok])
        for _ in range(self.depth - 1):
            h = jnp.tanh(h @ params["wh"])
        return h

    def _prefill_impl(self, params, toks):
        h0 = jnp.zeros((toks.shape[0], self.d_model), jnp.float32)

        def body(h, tok):
            h = self._step(params, h, tok)
            return h, None

        h, _ = jax.lax.scan(body, h0, toks.T)
        logits = (h @ params["wout"])[:, None, :]
        return logits, {"h": h}

    def prefill(self, params, batch, smax: int):
        """tokens (1, S) -> logits (1, 1, V), cache {"h": (1, D)}."""
        del smax
        return self._jit_prefill(params, batch["tokens"])

    def _prefill_batch_impl(self, params, toks, lengths):
        h0 = jnp.zeros((toks.shape[0], self.d_model), jnp.float32)

        def body(h, tok):
            h = self._step(params, h, tok)
            return h, h

        _, hs = jax.lax.scan(body, h0, toks.T)        # (S, B, D)
        idx = (lengths.astype(jnp.int32) - 1)[None, :, None]
        idx = jnp.broadcast_to(idx, (1, hs.shape[1], hs.shape[2]))
        h = jnp.take_along_axis(hs, idx, axis=0)[0]   # (B, D)
        logits = (h @ params["wout"])[:, None, :]
        return logits, {"h": h}

    def prefill_batch(self, params, batch, smax: int):
        """tokens (B, S) right-padded + lengths (B,) -> logits (B, 1, V),
        cache {"h": (B, D)} taken at each row's last *real* token, so
        padding is exact (see module docstring)."""
        del smax
        return self._jit_prefill_batch(params, batch["tokens"],
                                       batch["lengths"])

    def _decode_impl(self, params, caches, token):
        h = self._step(params, caches["h"], token[:, 0])
        return (h @ params["wout"])[:, None, :], {"h": h}

    def decode_step(self, params, caches, token, pos):
        """token (B, 1) int32; returns logits (B, 1, V) and new cache."""
        del pos
        return self._jit_decode(params, caches, token)
