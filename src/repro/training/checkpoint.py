"""Fault-tolerant checkpointing: atomic commit, async save, integrity
hashes, auto-resume, retention.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json        # step, leaf paths, shapes, dtypes, hashes
        <leaf-000>.npy ...   # one file per pytree leaf

Crash safety: leaves are written into ``step_N.tmp`` and the directory is
atomically renamed only after every file is fsync'd and the manifest is
written -- a half-written checkpoint can never be mistaken for a valid one.
``latest_step`` only considers directories with a readable manifest whose
hashes verify (configurable). Async mode hands the (host-copied) pytree to
a writer thread so the train loop never blocks on I/O.

Elasticity: checkpoints store *unsharded* leaves; on restore the trainer
re-shards onto whatever mesh is current (tests/test_checkpoint.py exercises
save on one topology, resume on another).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf-{i:05d}.npy"


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = False,
                 verify_hashes: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.verify_hashes = verify_hashes
        os.makedirs(directory, exist_ok=True)
        self._q: Optional[queue.Queue] = None
        self._thread = None
        self._errors: list = []
        if async_save:
            self._q = queue.Queue(maxsize=2)
            self._thread = threading.Thread(target=self._writer, daemon=True)
            self._thread.start()

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._q is not None:
            self._q.put((step, host_tree))
        else:
            self._write(step, host_tree)

    def wait(self) -> None:
        """Block until pending async saves are durable."""
        if self._q is not None:
            self._q.join()
        if self._errors:
            raise RuntimeError(f"async checkpoint failed: {self._errors[0]}")

    def _writer(self) -> None:
        while True:
            step, tree = self._q.get()
            try:
                self._write(step, tree)
            except Exception as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host_tree) -> None:
        leaves, treedef = _flatten(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        entries = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            path = os.path.join(tmp, _leaf_name(i))
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            entries.append({
                "file": _leaf_name(i),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            })
        manifest = {"step": step, "treedef": str(treedef), "leaves": entries}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (shape/dtype checked)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(leaves_like)}")
        out = []
        for i, (entry, ref) in enumerate(zip(manifest["leaves"], leaves_like)):
            arr = np.load(os.path.join(d, entry["file"]))
            if self.verify_hashes:
                h = hashlib.sha256(arr.tobytes()).hexdigest()
                if h != entry["sha256"]:
                    raise IOError(f"hash mismatch in {entry['file']} (corrupt checkpoint)")
            if list(arr.shape) != list(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
            out.append(arr.astype(ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like)
