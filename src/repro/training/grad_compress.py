"""Gradient compression for cross-pod data parallelism: int8 quantization
with error feedback.

Two forms:

* :func:`compress_decompress` -- quantize/dequantize with a persistent
  error-feedback residual; wraps any gradient tree (what the trainer uses,
  independent of mesh topology);
* :func:`compressed_psum` -- the shard_map building block that performs the
  actual 8-bit all-reduce over a mesh axis (each shard quantizes, psums the
  int32 accumulators, dequantizes), for explicit cross-pod reductions.

Error feedback keeps the quantization *unbiased over time*: the residual
(g - dequant(quant(g))) is added back into the next step's gradient, which
is what makes 8-bit DP converge (1-bit Adam / EF-SGD lineage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def _quant(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(g / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(grads, err_state):
    """Returns (compressed-then-restored grads, new error state)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant(g32)
        out = _dequant(q, scale)
        return out.astype(g.dtype), g32 - out
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def compressed_psum(x, axis_name: str):
    """8-bit all-reduce over ``axis_name`` (use inside shard_map): agree on
    a global scale (scalar pmax -- negligible traffic), quantize locally,
    sum int32 partials, dequantize once. ~4x less ICI/DCN traffic than an
    fp32 psum; error bounded by one global quantization step."""
    x = x.astype(jnp.float32)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale
