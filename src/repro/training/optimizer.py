"""AdamW optimizer (pure-pytree), cosine schedule, global-norm clipping.

``state_dtype="bfloat16"`` halves optimizer-state HBM (m, v in bf16 with
fp32 math per step) -- required to fit deepseek-v3 training state on a v5e
pod (DESIGN.md section 9).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Optional[str] = None     # None -> fp32 m/v


def lr_at(cfg: OptimizerConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_state(cfg: OptimizerConfig, params):
    dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else None

    def zeros(p):
        return jnp.zeros(p.shape, dt or jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
