"""Training step construction: grad accumulation, optional gradient
compression (error feedback), remat-aware loss, metrics.

The returned ``train_step(state, batch) -> (state, metrics)`` is what the
launcher jits with FSDP/TP shardings and what the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import grad_compress, optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    grad_accum: int = 1
    compress_grads: bool = False
    opt: opt.OptimizerConfig = dataclasses.field(default_factory=opt.OptimizerConfig)


def init_state(model, train_cfg: TrainConfig, key):
    params = model.init_params(key)
    state = {"params": params, "opt": opt.init_state(train_cfg.opt, params)}
    if train_cfg.compress_grads:
        state["ef"] = grad_compress.init_error_state(params)
    return state


def make_train_step(model, train_cfg: TrainConfig):
    accum = train_cfg.grad_accum

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, gsum, grads), lsum + loss), None
            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batches)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            (loss, _), grads = grad_fn(params, batch)
        new_state = dict(state)
        if train_cfg.compress_grads:
            grads, new_ef = grad_compress.compress_decompress(grads, state["ef"])
            new_state["ef"] = new_ef
        new_params, new_opt, om = opt.apply_updates(
            train_cfg.opt, params, grads, state["opt"])
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step
