import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    # pytest.ini sets a per-test ceiling via pytest-timeout; register the
    # marker here too so per-test `@pytest.mark.timeout(...)` overrides do
    # not warn when the plugin is absent (bare containers).
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test ceiling (pytest-timeout)")
