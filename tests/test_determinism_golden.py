"""Golden determinism: two same-seed sims in one process are identical.

This is the regression fence for the queue/clock overhaul: tie counters
and event sequence numbers are per-instance now, so building a second
kernel in the same process must not perturb the first's tie-break order.
Identity is asserted at the strictest observable level -- the full
``Metrics.summary()`` JSON and the ``TraceSummary`` JSON, byte for byte.
"""
import json
import random

import pytest

from repro.core import Job, Tier, build_kernel
from repro.core.task import AcquireLock, Block, Burst, ReleaseLock
from repro.core.workloads import bound_worker, bursty_worker

HORIZON = 0.4
WARMUP = 0.1


def _holder(lock):
    while True:
        yield AcquireLock(lock)
        yield Burst(0.4e-3)
        yield ReleaseLock(lock)


def _waiter(lock, seed):
    rng = random.Random(seed)
    while True:
        yield Block(rng.uniform(0.3e-3, 0.8e-3))
        yield AcquireLock(lock)
        yield Burst(0.1e-3)
        yield ReleaseLock(lock)


def _run_once(policy: str) -> tuple:
    """One mixed sim with lock churn (boosts exercise keyed removal)."""
    k = build_kernel("sim", policy=policy, n_slots=2, trace=True, seed=7)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000.0)
    bg = k.create_group("bg", Tier.BACKGROUND, 1.0)
    for i in range(3):
        k.add_job(Job(ts, behavior=bursty_worker(i), name=f"ts-{i}",
                      kind="bursty"))
    for i in range(24):
        k.add_job(Job(bg, behavior=bound_worker(50 + i, query_cpu=0.01),
                      name=f"bg-{i}", kind="bound"))
    lock = k.create_lock("l0")
    k.add_job(Job(bg, behavior=_holder(lock), name="holder", kind="holder"))
    k.add_job(Job(ts, behavior=_waiter(lock, 99), name="waiter",
                  kind="waiter"))
    m = k.run(HORIZON, warmup=WARMUP)
    summary = json.dumps(m.summary(n_slots=2), sort_keys=True)
    trace = k.tracer.summary().to_json()
    return summary, trace


@pytest.mark.parametrize("policy", ["ufs", "vdf", "fifo", "rr"])
def test_same_seed_runs_are_byte_identical(policy):
    s1, t1 = _run_once(policy)
    s2, t2 = _run_once(policy)
    assert s1 == s2
    assert t1 == t2


def test_runs_do_real_work():
    """Guard against the golden comparison passing vacuously."""
    s, t = _run_once("ufs")
    summary = json.loads(s)
    trace = json.loads(t)
    assert summary["groups"]["ts"]["cpu_s"] > 0
    assert trace["events"] > 100
    assert trace["counts"].get("boost", 0) > 0   # churn exercised removal
