"""Distribution tests: sharding rules, multi-device GSPMD compile of the
real train/serve steps, pipeline parallelism, compressed psum -- run in
subprocesses with forced host device counts (the main process must keep the
default single device)."""
import json
import os
import subprocess
import sys

import pytest

from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


# ------------------------------------------------------------ spec rules
def test_param_spec_rules_single_device():
    import jax
    import jax.numpy as jnp
    from repro.distributed.sharding import param_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeKey:
        def __init__(self, k):
            self.key = k

    leaf = jnp.zeros((64, 128))
    spec = param_spec((FakeKey("attn"), FakeKey("wq"), FakeKey("w")), leaf, mesh)
    assert spec == P(None, None)          # size-1 axes -> replicate


def test_param_spec_rules_16x16():
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import params_shardings
from repro.configs import get_arch
from repro.models.transformer import Model

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_arch("llama3.2-1b").reduced()
m = Model(cfg)
shapes = jax.eval_shape(lambda: m.init_params(jax.random.PRNGKey(0)))
sh = params_shardings(shapes, mesh)
import jax.tree_util as jtu
flat = jtu.tree_flatten_with_path(sh)[0]
specs = {jtu.keystr(p): s.spec for p, s in flat}
# scanned leaves carry a leading (unsharded) layer dim;
# column-parallel wq: out dim on model, in dim on data (FSDP)
wq = [v for k, v in specs.items() if "wq" in k and "'w'" in k][0]
assert wq == P(None, "data", "model"), wq
wo = [v for k, v in specs.items() if "'wo'" in k and "'w'" in k][0]
assert wo == P(None, "model", "data"), wo
emb = [v for k, v in specs.items() if "table" in k][0]
assert emb == P(None, "model"), emb
print("SPECS-OK")
"""
    assert "SPECS-OK" in run_sub(code, devices=8)


# ----------------------------------------------------- multi-device compile
@pytest.mark.slow
def test_train_step_compiles_and_runs_on_4x2_mesh():
    """The real train_step (FSDP+TP shardings) compiles AND executes on 8
    host devices; loss finite; params stay sharded."""
    code = """
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.distributed import sharding
from repro.models.transformer import Model
from repro.training import optimizer as opt, trainer as T

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_arch("qwen2-0.5b").reduced()
model = Model(cfg)
tcfg = T.TrainConfig(grad_accum=2, opt=opt.OptimizerConfig(lr=1e-3))
state = T.init_state(model, tcfg, jax.random.PRNGKey(0))
state_shard = {
    "params": sharding.params_shardings(state["params"], mesh),
    "opt": sharding.params_shardings(state["opt"], mesh),
}
state = jax.device_put(state, state_shard)
batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
         "labels": jnp.zeros((8, 32), jnp.int32)}
batch = jax.device_put(batch, sharding.batch_shardings(batch, mesh))
step = jax.jit(T.make_train_step(model, tcfg),
               in_shardings=(state_shard, sharding.batch_shardings(batch, mesh)),
               out_shardings=(state_shard, None))
state, m = step(state, batch)
assert jnp.isfinite(m["loss"])
wq = state["params"]["segments"][0]["attn"]["wq"]["w"]
assert len(wq.sharding.device_set) == 8
print("TRAIN-8DEV-OK", float(m["loss"]))
"""
    assert "TRAIN-8DEV-OK" in run_sub(code, devices=8)


@pytest.mark.slow
def test_decode_step_compiles_on_multi_pod_mini_mesh():
    """serve_step lowers+compiles on a (2,2,2) pod/data/model mesh -- the
    multi-pod path in miniature."""
    code = """
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.distributed import sharding
from repro.models.transformer import Model

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_arch("llama3.2-1b").reduced()
model = Model(cfg)
params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
p_shard = sharding.params_shardings(params, mesh)
caches = jax.eval_shape(lambda: model.init_cache(8, 64))
cspec = model.cache_pspecs(mesh, 8, 64)
cshard = jax.tree.map(lambda ps: jax.sharding.NamedSharding(mesh, ps), cspec,
                      is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
tshard = sharding.batch_shardings({"t": tok}, mesh)["t"]
lowered = jax.jit(model.decode_step,
                  in_shardings=(p_shard, cshard, tshard, None),
                  out_shardings=(None, cshard)).lower(
    params, caches, tok, jax.ShapeDtypeStruct((), jnp.int32))
compiled = lowered.compile()
assert compiled.cost_analysis() is not None
print("DECODE-MULTIPOD-OK")
"""
    assert "DECODE-MULTIPOD-OK" in run_sub(code, devices=8)


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    """GPipe over a 4-stage 'pod' axis == sequential layer application."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.pipeline_parallel import make_pipelined_fn

mesh = jax.make_mesh((4,), ("pod",))
n_stages, n_micro, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))
pipe = make_pipelined_fn(stage_fn, mesh, n_stages, "pod",
                         param_specs=P("pod"))
y_pipe = pipe(ws, x)
y_seq = x
for s in range(n_stages):
    y_seq = jax.vmap(lambda xb: stage_fn(ws[s], xb))(y_seq)
err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
assert err < 1e-5, err
print("PIPE-OK", err)
"""
    assert "PIPE-OK" in run_sub(code, devices=8)


@pytest.mark.slow
def test_compressed_psum_multi_device():
    """int8 error-feedback all-reduce across a real 4-way axis."""
    code = """
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.training.grad_compress import compressed_psum

mesh = jax.make_mesh((4,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 128))
f = shard_map(lambda a: compressed_psum(a[0], "pod"), mesh=mesh,
              in_specs=P("pod"), out_specs=P())
y = f(x)
ref = jnp.sum(x, axis=0)
rel = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
assert rel < 0.03, rel
print("CPSUM-OK", rel)
"""
    assert "CPSUM-OK" in run_sub(code, devices=8)
