"""Property tests: the indexed-heap dispatch queue vs a naive reference.

The reference model is the previous implementation -- a ``bisect``-sorted
list of ``(key, tie, job)`` tuples -- driven through the same operation
sequence.  Any observable divergence (pop order, removal results,
``jobs()`` listing, lengths) is a bug in the heap's lazy-deletion
bookkeeping.
"""
import bisect
import itertools
import random

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:          # seeded-random differential test still runs
    HAVE_HYPOTHESIS = False

from repro.core.dsq import GroupDSQ, LocalDSQ, _OrderedQueue


class _FakeJob:
    """The queue only reads ``.jid``; no need for a full Job."""

    _ids = itertools.count()

    def __init__(self):
        self.jid = next(self._ids)

    def __repr__(self):  # pragma: no cover
        return f"J{self.jid}"


class _ReferenceQueue:
    """The old sorted-list queue, kept as the executable specification."""

    def __init__(self):
        self._items = []
        self._tie = itertools.count()

    def __len__(self):
        return len(self._items)

    def push(self, job, key):
        bisect.insort(self._items, (key, next(self._tie), job))

    def pop_front(self):
        return self._items.pop(0)[2] if self._items else None

    def peek_front(self):
        return self._items[0][2] if self._items else None

    def peek_key(self):
        return self._items[0][0] if self._items else None

    def pop_back(self):
        return self._items.pop()[2] if self._items else None

    def pop_first_where(self, pred):
        for i, (_, _, j) in enumerate(self._items):
            if pred(j):
                del self._items[i]
                return j
        return None

    def remove(self, job):
        for i, (_, _, j) in enumerate(self._items):
            if j is job:
                del self._items[i]
                return True
        return False

    def jobs(self):
        return [j for _, _, j in self._items]


_OP_NAMES = ["push", "pop_front", "peek", "remove", "remove_absent",
             "pop_first_where", "pop_back", "jobs"]


def _check_op_sequence(ops):
    """Drive both queues through ``ops`` asserting observable equality."""
    q, ref = _OrderedQueue(), _ReferenceQueue()
    alive = []                       # jobs pushed and not yet popped/removed
    for op, key, pick in ops:
        if op == "push":
            j = _FakeJob()
            q.push(j, key)
            ref.push(j, key)
            alive.append(j)
        elif op == "pop_front":
            a, b = q.pop_front(), ref.pop_front()
            assert a is b
            if a is not None:
                alive.remove(a)
        elif op == "peek":
            assert q.peek_front() is ref.peek_front()
            assert q.peek_key() == ref.peek_key()
        elif op == "remove" and alive:
            j = alive[pick % len(alive)]
            assert q.remove(j) == ref.remove(j)
            alive.remove(j)
        elif op == "remove_absent":
            j = _FakeJob()           # never pushed
            assert q.remove(j) is False and ref.remove(j) is False
        elif op == "pop_first_where":
            pred = lambda j, m=(pick % 3) + 1: j.jid % m == 0
            a, b = q.pop_first_where(pred), ref.pop_first_where(pred)
            assert a is b
            if a is not None:
                alive.remove(a)
        elif op == "pop_back":
            a, b = q.pop_back(), ref.pop_back()
            assert a is b
            if a is not None:
                alive.remove(a)
        elif op == "jobs":
            assert q.jobs() == ref.jobs()
        assert len(q) == len(ref)
        assert bool(q) == bool(ref)
    # Drain both: full pop order must agree.
    while True:
        a, b = q.pop_front(), ref.pop_front()
        assert a is b
        if a is None:
            break


def test_randomized_against_reference():
    """Seeded-random differential run (no hypothesis dependency): pushes
    are weighted so queues actually grow deep enough to stress lazy
    deletion and compaction."""
    rng = random.Random(1337)
    for _ in range(40):
        ops = [(rng.choice(_OP_NAMES + ["push", "push"]),
                round(rng.uniform(0.0, 10.0), 3), rng.randrange(64))
               for _ in range(rng.randrange(10, 120))]
        _check_op_sequence(ops)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_indexed_heap_matches_reference_hypothesis():
    _OPS = st.sampled_from(_OP_NAMES)
    _KEYS = st.floats(min_value=0.0, max_value=10.0, allow_nan=False)

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.tuples(_OPS, _KEYS, st.integers(0, 30)), max_size=60))
    def run(ops):
        _check_op_sequence(ops)

    run()


def test_double_push_supersedes():
    """Re-pushing a queued job replaces its old cell (never two live cells)."""
    q = LocalDSQ()
    j = _FakeJob()
    q.push(j, 5.0)
    q.push(j, 1.0)
    assert len(q) == 1
    assert q.pop_front() is j
    assert len(q) == 0
    assert q.pop_front() is None


def test_pred_exception_loses_nothing():
    """A raising predicate must not drop skipped entries."""
    q = GroupDSQ()
    jobs = [_FakeJob() for _ in range(5)]
    for i, j in enumerate(jobs):
        q.push(j, float(i))

    def pred(j):
        if j is jobs[3]:
            raise RuntimeError("boom")
        return False

    with pytest.raises(RuntimeError):
        q.pop_first_where(pred)
    assert len(q) == 5
    assert [q.pop_front() for _ in range(5)] == jobs


def test_per_queue_tie_counters_are_independent():
    """Two queues built in one process see identical tie sequences: FIFO
    order among equal keys depends only on per-queue push order."""
    for _ in range(2):
        q = LocalDSQ()
        jobs = [_FakeJob() for _ in range(8)]
        for j in jobs:
            q.push(j, 1.0)           # all-equal keys: pure FIFO
        assert [q.pop_front() for _ in range(8)] == jobs


def test_compaction_bounds_dead_cells():
    """Mass removal compacts the heap: dead cells never dominate."""
    q = GroupDSQ()
    jobs = [_FakeJob() for _ in range(512)]
    for i, j in enumerate(jobs):
        q.push(j, float(i))
    for j in jobs[::2]:
        assert q.remove(j)
    assert len(q) == 256
    # Lazy deletion keeps some dead cells, but compaction caps them.
    assert q._dead * 2 <= len(q._heap) + 1
    assert q.jobs() == jobs[1::2]
