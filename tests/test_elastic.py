"""Elasticity + fault tolerance at the system level: checkpoint on one
topology, resume on another; bulk (background-tier) serving admission."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_checkpoint_resumes_on_different_mesh(tmp_path):
    """Save sharded train state on a (4,2) mesh, restore onto (2,2):
    checkpoints are topology-independent (unsharded leaves + re-shard on
    load), the elastic-rescale contract of DESIGN.md section 6."""
    script = f"""
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.distributed import sharding
from repro.models.transformer import Model
from repro.training import optimizer as opt, trainer as T
from repro.training.checkpoint import CheckpointManager

def build(mesh):
    cfg = get_arch("llama3.2-1b").reduced()
    model = Model(cfg)
    tcfg = T.TrainConfig(opt=opt.OptimizerConfig(lr=1e-3))
    state = T.init_state(model, tcfg, jax.random.PRNGKey(0))
    shard = {{"params": sharding.params_shardings(state["params"], mesh),
             "opt": sharding.params_shardings(state["opt"], mesh)}}
    return cfg, model, tcfg, state, shard

mesh_a = jax.make_mesh((4, 2), ("data", "model"))
cfg, model, tcfg, state, shard_a = build(mesh_a)
state = jax.device_put(state, shard_a)
step = jax.jit(T.make_train_step(model, tcfg))
batch = {{"tokens": jnp.ones((8, 16), jnp.int32),
         "labels": jnp.ones((8, 16), jnp.int32)}}
state, _ = step(state, batch)
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save(1, state)

# 'scale down': restore the same checkpoint onto a 2x2 mesh
mesh_b = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
shard_b = {{"params": sharding.params_shardings(state["params"], mesh_b),
           "opt": sharding.params_shardings(state["opt"], mesh_b)}}
stepn, restored = mgr.restore_latest(jax.tree.map(lambda x: x, state))
restored = jax.device_put(restored, shard_b)
state2, m = jax.jit(T.make_train_step(model, tcfg),
                    in_shardings=(shard_b, None),
                    out_shardings=(shard_b, None))(restored, batch)
assert jnp.isfinite(m["loss"])
wq = state2["params"]["segments"][0]["attn"]["wq"]["w"]
assert len(wq.sharding.device_set) == 4
print("ELASTIC-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC-OK" in out.stdout


@pytest.mark.slow
def test_bulk_prefill_is_background_tier():
    """A bulk request's prefill runs as a background job: with a
    time-sensitive decode stream active, the bulk job is only dispatched
    in slack, and the decode stream's latency stays flat."""
    import time
    from repro.configs import get_arch
    from repro.core import Tier
    from repro.core.live import LiveKernel
    from repro.core.policies import make_policy
    from repro.models.transformer import Model
    from repro.serving.engine import InferenceEngine, Request

    cfg = get_arch("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    kernel = LiveKernel(1, make_policy("ufs"))
    engine = InferenceEngine(model, params, kernel, max_batch=4, max_len=64)
    kernel.start()
    engine.start()
    rng = np.random.default_rng(0)
    interactive = engine.submit(Request(
        prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
        max_new_tokens=6))
    bulk = engine.submit(Request(
        prompt=rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
        max_new_tokens=2, tier="background"))
    assert interactive.done_event.wait(timeout=180)
    assert bulk.done_event.wait(timeout=180)
    engine.stop()
    kernel.stop()
    assert len(interactive.tokens) >= 6
    assert len(bulk.tokens) >= 2
    assert kernel.metrics.cpu_by_group.get("serve-bulk", 0.0) > 0.0
