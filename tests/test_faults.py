"""Fault containment: panic path, lock hygiene, retry/quarantine, and the
crash-injection harness (DESIGN.md section 12).

The end-to-end containment tests are the acceptance shape of ISSUE 9: a
background job that raises *while holding an engine lock* must be traced
and counted as a panic, its lock force-released, its boost expired, the
waiting time-sensitive job must proceed, and after N failed retries the
job must be quarantined -- under both the sim and the thread backend.
"""
import threading
import time

import pytest

from repro.core import (Job, RetryPolicy, SchedKernel, SchedTracer, Tier,
                        make_policy)
from repro.core.faults import (FaultInjected, FaultInjector, crashing_chunk,
                               crashing_holder, crashy_behavior, occupy_lock)
from repro.core.live import LiveJob, LiveKernel, LiveLock
from repro.core.task import (AcquireLock, Block, Burst, JobState, ReleaseLock,
                             RequestBegin, RequestEnd)
from repro.core.locks import spin_acquire


def _wait_for(cond, timeout=5.0, interval=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


def _kinds(tracer):
    return [ev.kind for ev in tracer.events]


# ---------------------------------------------------------------------------
# Injector harness
# ---------------------------------------------------------------------------

def test_injector_fires_deterministically():
    inj = FaultInjector({"chunk": 3})
    assert [inj.fires("chunk") for _ in range(5)] == [False, False, True,
                                                     False, False]
    assert inj.hits["chunk"] == 5 and inj.fired["chunk"] == 1
    # unplanned sites never fire but are still counted
    assert not inj.fires("other") and inj.hits["other"] == 1


def test_injector_repeat_models_crash_loop():
    inj = FaultInjector({"chunk": 2}, repeat=True)
    assert [inj.fires("chunk") for _ in range(4)] == [False, True, True, True]
    with pytest.raises(FaultInjected):
        inj.check("chunk")


def test_crashy_behavior_raises_mid_stream():
    inj = FaultInjector({"sim": 2})
    gen = crashy_behavior(inj, [Burst(1e-3), Burst(1e-3), Burst(1e-3)],
                          site="sim")
    assert isinstance(next(gen), Burst)
    with pytest.raises(FaultInjected):
        next(gen)


# ---------------------------------------------------------------------------
# Satellite 1: LiveLock.acquire timeout must not leak the wait entry
# ---------------------------------------------------------------------------

def test_livelock_timeout_cleans_wait_entry_and_boost():
    tracer = SchedTracer()
    k = LiveKernel(1, make_policy("ufs"), tracer=tracer)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = LiveLock(k, "shared")
    holder = LiveJob(bg, lambda b: "yield", name="holder")
    waiter = LiveJob(ts, lambda b: "yield", name="waiter")

    assert lock.acquire(holder)
    assert not lock.acquire(waiter, timeout=0.05)
    # The boost fired while the TS waiter was registered...
    assert k.hints.boosts == 1
    # ...but the timeout retracted the wait entry and expired the boost,
    # instead of leaving the holder boosted forever.
    assert k.hints.waiters == {}
    assert holder.boosted is False
    assert "lock_timeout" in _kinds(tracer)
    lock.release(holder)
    assert holder.held_locks == set()


def test_occupy_lock_drives_timeout_path():
    k = LiveKernel(1, make_policy("ufs"))
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = LiveLock(k, "occupied")
    squatter = LiveJob(bg, lambda b: "yield", name="squatter")
    victim = LiveJob(bg, lambda b: "yield", name="victim")
    release = occupy_lock(lock, squatter)
    try:
        assert not lock.acquire(victim, timeout=0.02)
    finally:
        release.set()
    assert _wait_for(lambda: lock.holder is None)
    assert lock.acquire(victim, timeout=1.0)
    lock.release(victim)


# ---------------------------------------------------------------------------
# Satellite 2: worker exceptions are panics, not silent "done"
# ---------------------------------------------------------------------------

def test_live_worker_exception_routes_to_panic():
    tracer = SchedTracer()
    k = LiveKernel(1, make_policy("ufs"), tracer=tracer)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)

    def chunk(budget):
        raise ValueError("boom in chunk")
    job = LiveJob(bg, chunk, name="crasher")

    k.start()
    k.wake(job)
    assert _wait_for(lambda: job.state == JobState.EXITED)
    k.stop()

    assert k.metrics.panics == ["crasher"]
    assert job.panic and job.quarantined
    assert "ValueError" in job.last_panic
    panics = [ev for ev in tracer.events if ev.kind == "panic"]
    assert len(panics) == 1
    # the traceback is captured in the trace event, not swallowed
    assert "ValueError: boom in chunk" in panics[0].args["traceback"]
    stops = [ev for ev in tracer.events if ev.kind == "stop_job"]
    assert stops and stops[-1].args["reason"] == "panic"


def test_live_retry_then_quarantine():
    tracer = SchedTracer()
    k = LiveKernel(1, make_policy("ufs"), tracer=tracer)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    inj = FaultInjector({"chunk": 1}, repeat=True)      # crash every chunk
    job = LiveJob(bg, crashing_chunk(inj), name="looper",
                  retry_policy=RetryPolicy(max_retries=2, backoff=0.005))

    k.start()
    k.wake(job)
    assert _wait_for(lambda: job.quarantined)
    k.stop()

    assert job.retries == 2
    assert k.metrics.panics == ["looper"] * 3           # initial + 2 retries
    assert k.metrics.retries == 2 and k.metrics.quarantines == 1
    kinds = _kinds(tracer)
    assert kinds.count("panic") == 3
    assert kinds.count("retry") == 2
    assert kinds.count("quarantine") == 1
    # quarantined for good: wake() must refuse the poisoned job
    k.wake(job)
    assert job.state == JobState.EXITED
    # summary surfaces the fault counters on faulting runs
    counters = k.metrics.summary()["counters"]
    assert counters["retries"] == 2 and counters["quarantines"] == 1


# ---------------------------------------------------------------------------
# End-to-end containment (the acceptance scenario), sim backend
# ---------------------------------------------------------------------------

def test_sim_panic_containment_end_to_end():
    tracer = SchedTracer()
    k = SchedKernel(1, make_policy("ufs"), tracer=tracer)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("shared")

    holder = Job(bg, behavior_factory=crashing_holder(lock, hold_cpu=5e-3),
                 name="crashy-holder", kind="bound",
                 retry_policy=RetryPolicy(max_retries=1, backoff=1e-3))

    def waiter_behavior():
        yield Block(1e-3)            # let the holder take the lock first
        yield RequestBegin()
        yield AcquireLock(lock)
        yield Burst(1e-3)
        yield ReleaseLock(lock)
        yield RequestEnd()
    waiter = Job(ts, behavior=waiter_behavior(), name="ts-waiter",
                 kind="bursty")

    k.add_job(holder)
    k.add_job(waiter)
    m = k.run(1.0)

    # panic traced + counted, retried once, then quarantined
    assert m.panics == ["crashy-holder"] * 2
    assert m.retries == 1 and m.quarantines == 1
    assert holder.quarantined and holder.state == JobState.EXITED
    # lock force-released and boost expired
    assert lock.holder is None and holder.held_locks == set()
    assert holder.boosted is False
    assert k.hints.waiters == {} and k.hints._boost_reasons == {}
    # the waiting time-sensitive job proceeded to completion
    assert waiter.completed_requests == 1
    kinds = _kinds(tracer)
    assert kinds.count("panic") == 2
    assert kinds.count("retry") == 1
    assert kinds.count("quarantine") == 1
    assert "boost" in kinds          # the inversion actually happened


def test_sim_exit_while_holding_hands_off_to_parked_waiter():
    """A job that *exits* (not panics) holding a sleep-discipline lock must
    resume the parked waiter the release grants the lock to."""
    k = SchedKernel(1, make_policy("ufs"))
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("leaky")

    def holder_then_exit():
        yield AcquireLock(lock)
        yield Burst(2e-3)            # StopIteration while holding the lock

    def waiter_behavior():
        yield Block(0.5e-3)
        yield RequestBegin()
        yield AcquireLock(lock)
        yield Burst(0.5e-3)
        yield ReleaseLock(lock)
        yield RequestEnd()
    waiter = Job(ts, behavior=waiter_behavior(), name="parked-waiter")

    k.add_job(Job(bg, behavior=holder_then_exit(), name="exiting-holder"))
    k.add_job(waiter)
    k.run(1.0)
    assert waiter.completed_requests == 1
    assert waiter.state == JobState.EXITED
    assert lock.holder is None


def test_sim_panic_without_factory_quarantines_immediately():
    """A retry policy cannot restart a dead generator without a
    behavior_factory: the job is quarantined instead of crash-looping."""
    k = SchedKernel(1, make_policy("ufs"))
    bg = k.create_group("bg", Tier.BACKGROUND, 1)

    def crashes():
        yield Burst(1e-3)
        raise FaultInjected("no factory")
    job = Job(bg, behavior=crashes(), name="one-shot",
              retry_policy=RetryPolicy(max_retries=5))
    k.add_job(job)
    m = k.run(0.5)
    assert m.panics == ["one-shot"]
    assert m.retries == 0 and m.quarantines == 1
    assert job.quarantined


def test_spinlock_panic_exit_quarantines():
    """The stuck-spinlock watchdog (PanicExit) flows through the same
    containment path: counted, quarantined, locks clean."""
    k = SchedKernel(2, make_policy("ufs"))
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    lock = k.create_lock("stuck")

    def stuck_holder():
        yield AcquireLock(lock)
        while True:
            yield Burst(1e-3)        # never releases

    def spinner():
        yield Burst(1e-4)
        yield from spin_acquire(lock, panic_attempts=3)
        yield ReleaseLock(lock)
    victim = Job(ts, behavior=spinner(), name="spinner")

    k.add_job(Job(ts, behavior=stuck_holder(), name="stuck-holder"))
    k.add_job(victim)
    m = k.run(1.0)
    assert m.panics == ["spinner"]
    assert m.quarantines == 1
    assert victim.panic and victim.quarantined
    assert victim.held_locks == set()


# ---------------------------------------------------------------------------
# End-to-end containment (the acceptance scenario), live backend
# ---------------------------------------------------------------------------

def test_live_panic_containment_end_to_end():
    tracer = SchedTracer()
    k = LiveKernel(2, make_policy("ufs"), tracer=tracer)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("shared")
    waiter_done = threading.Event()

    holder = LiveJob(bg, lambda b: "yield", name="crashy-holder",
                     retry_policy=RetryPolicy(max_retries=1, backoff=0.01))

    def holder_chunk(budget):
        lock.acquire(holder)
        time.sleep(0.05)             # hold while the TS waiter arrives
        raise RuntimeError("boom while holding")
    holder._run_chunk = holder_chunk

    waiter = LiveJob(ts, lambda b: "yield", name="ts-waiter")

    def waiter_chunk(budget):
        if lock.acquire(waiter, timeout=2.0):
            lock.release(waiter)
            waiter_done.set()
            return "done"
        return "yield"
    waiter._run_chunk = waiter_chunk

    k.start()
    k.wake(holder)
    assert _wait_for(lambda: lock.holder is holder)
    k.wake(waiter)
    # the TS job proceeds because the panic force-released the lock
    assert waiter_done.wait(5.0)
    assert _wait_for(lambda: holder.quarantined)
    k.stop()

    assert k.metrics.panics == ["crashy-holder"] * 2    # initial + 1 retry
    assert k.metrics.retries == 1 and k.metrics.quarantines == 1
    assert holder.state == JobState.EXITED
    assert holder.boosted is False and holder.held_locks == set()
    assert k.hints.waiters == {} and k.hints._boost_reasons == {}
    assert lock.holder is None and not lock._lock.locked()
    kinds = _kinds(tracer)
    assert kinds.count("panic") == 2
    assert kinds.count("quarantine") == 1


# ---------------------------------------------------------------------------
# Satellite 4: drain_slot while a live job is mid-chunk
# ---------------------------------------------------------------------------

def test_drain_slot_mid_chunk_live():
    tracer = SchedTracer()
    k = LiveKernel(2, make_policy("ufs"), tracer=tracer)
    bg = k.create_group("bg", Tier.BACKGROUND, 100)
    ran = []

    def chunk(budget):
        time.sleep(0.02)
        ran.append(time.monotonic())
        return "yield"
    job = LiveJob(bg, chunk, name="migrant")

    k.start()
    k.wake(job)
    assert _wait_for(lambda: job.state == JobState.RUNNING)
    drained = next(s.sid for s in k.slots if s.current is job)
    k.drain_slot(drained)
    drain_t = k.now
    # the job keeps making progress on the surviving slot
    n_before = len(ran)
    assert _wait_for(lambda: len(ran) >= n_before + 3)
    k.stop()

    assert not k.slots[drained].online
    starts_after = [ev for ev in tracer.events
                    if ev.kind == "start_job" and ev.t > drain_t]
    assert starts_after, "job never re-dispatched after the drain"
    # the drained slot is never re-dispatched; the survivor carries the job
    assert all(ev.slot != drained for ev in starts_after)
    assert any(ev.jid == job.jid for ev in starts_after)


# ---------------------------------------------------------------------------
# Fault-free runs: the subsystem must be invisible
# ---------------------------------------------------------------------------

def test_fault_free_summary_has_no_fault_keys():
    """Metrics.summary() on a fault-free run is byte-compatible with the
    pre-fault-path schema (the microbench baseline hashes it exactly)."""
    from repro.core.workloads import bound_worker, bursty_worker
    k = SchedKernel(2, make_policy("ufs"), seed=11)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    k.add_job(Job(ts, behavior=bursty_worker(1), name="ts0", kind="bursty"))
    k.add_job(Job(bg, behavior=bound_worker(2, query_cpu=0.05), name="bg0",
                  kind="bound"))
    m = k.run(0.5, warmup=0.1)
    counters = m.summary()["counters"]
    assert set(counters) == {"preemptions", "kicks", "dispatches",
                             "lb_migrations", "panics"}
    assert counters["panics"] == []
