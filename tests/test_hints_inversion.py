"""Application-based scheduler hinting + the Table 4 priority-inversion
micro-experiment."""
import pytest

from repro.core import Job, SchedKernel, Tier, make_policy
from repro.core.hints import HintTable
from repro.core.task import Block, Burst
from repro.core.workloads import burner, holder, waiter


def build(policy="ufs", with_burner=True, hints=True):
    k = SchedKernel(1, make_policy(policy), hints_enabled=hints)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("spin")
    h = Job(bg, behavior=holder(lock, compute=0.5), name="holder")
    w = Job(ts, behavior=waiter(lock, start_delay=0.05, compute=0.01), name="waiter")
    h.pinned_slot = w.pinned_slot = 0
    jobs = [h, w]
    if with_burner:
        b = Job(ts, behavior=burner(start_delay=0.1), name="burner")
        b.pinned_slot = 0
        jobs.append(b)
    for j in jobs:
        k.add_job(j)
    return k, lock, h, w


# ------------------------------------------------------------- unit level
def test_hint_table_boost_unboost_refcount():
    ht = HintTable()
    ts = __import__("repro.core.task", fromlist=["WorkloadGroup"])
    from repro.core.task import WorkloadGroup
    gts = WorkloadGroup("ts", Tier.TIME_SENSITIVE, 10000)
    gbg = WorkloadGroup("bg", Tier.BACKGROUND, 1)
    h = Job(gbg, behavior=iter(()))
    w = Job(gts, behavior=iter(()))
    ht.report_lock_acquired(h, 1)
    ht.report_lock_acquired(h, 2)
    ht.report_wait_start(w, 1)
    assert h.boosted and h.tier == Tier.TIME_SENSITIVE
    assert h.sched_group() is gts            # priority inheritance
    ht.report_lock_released(h, 2)
    assert h.boosted                         # still holds contended lock 1
    ht.report_lock_released(h, 1)
    assert not h.boosted and h.tier == Tier.BACKGROUND


def test_wait_start_idempotent():
    ht = HintTable()
    from repro.core.task import WorkloadGroup
    g = WorkloadGroup("ts", Tier.TIME_SENSITIVE, 10000)
    w = Job(g, behavior=iter(()))
    ht.report_wait_start(w, 7)
    ht.report_wait_start(w, 7)
    assert len(ht.waiters[7]) == 1


def test_bg_waiter_does_not_boost():
    ht = HintTable()
    from repro.core.task import WorkloadGroup
    gbg = WorkloadGroup("bg", Tier.BACKGROUND, 1)
    h = Job(gbg, behavior=iter(()))
    w = Job(gbg, behavior=iter(()))
    ht.report_lock_acquired(h, 1)
    ht.report_wait_start(w, 1)
    assert not h.boosted


# --------------------------------------------------------- Table 4 bands
def test_baseline_completes_fast():
    k, lock, h, w = build(with_burner=False)
    k.run(5.0)
    assert h.completed_requests == 1 and w.completed_requests == 1
    assert lock.acquired_at[w.jid] < 1.5


def test_ufs_hints_resolve_inversion():
    k, lock, h, w = build("ufs", hints=True)
    k.run(30.0)
    assert h.boost_count >= 1
    assert w.completed_requests == 1
    # holder boosted -> shares the slot ~50:50 with the burner: ~2x baseline
    assert lock.acquired_at[w.jid] < 3.0


def test_ufs_without_hints_starves():
    k, lock, h, w = build("ufs", hints=False)
    k.run(30.0)
    assert w.completed_requests == 0         # stuck behind the burner


def test_vdf_starves_waiter():
    k, lock, h, w = build("vdf", hints=False)
    k.run(30.0)
    assert w.completed_requests == 0


def test_fifo_waiter_never_polls():
    k, lock, h, w = build("fifo", hints=False)
    k.run(60.0)
    # fair server lets the holder finish eventually, but the waiter cannot
    # even poll behind the monopolizing burner
    assert w.jid not in lock.acquired_at


def test_rr_quantum_lets_waiter_through_eventually():
    k, lock, h, w = build("rr", hints=False)
    k.run(60.0)
    # holder limps at ~5% (fair server): 0.5s compute -> ~10s wall
    assert w.completed_requests == 1
    assert lock.acquired_at[w.jid] > 5.0
