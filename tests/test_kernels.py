"""Per-kernel validation: Pallas kernel bodies (interpret mode on CPU) and
the XLA blocked implementations, swept over shapes/dtypes against the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(shape, dtype=jnp.float32, k=0, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, k), shape) * scale).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("bh,sq,sk,d", [(4, 256, 256, 64), (2, 128, 256, 32),
                                        (1, 512, 512, 128), (3, 128, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_flash_attention_causal(bh, sq, sk, d, dtype, backend):
    q, k, v = rand((bh, sq, d), dtype, 1), rand((bh, sk, d), dtype, 2), rand((bh, sk, d), dtype, 3)
    r = ref.flash_attention_ref(q, k, v, causal=True)
    o = ops.flash_attention(q, k, v, causal=True, backend=backend,
                            block_q=128, block_k=128)
    assert jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))) < TOL[dtype]


@pytest.mark.parametrize("window", [64, 128])
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_flash_attention_sliding_window(window, backend):
    q, k, v = rand((2, 256, 32), k=1), rand((2, 256, 32), k=2), rand((2, 256, 32), k=3)
    r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            backend=backend, block_q=64, block_k=64)
    assert jnp.max(jnp.abs(o - r)) < 2e-5


@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_flash_attention_noncausal(backend):
    q, k, v = rand((2, 128, 64), k=4), rand((2, 128, 64), k=5), rand((2, 128, 64), k=6)
    r = ref.flash_attention_ref(q, k, v, causal=False)
    o = ops.flash_attention(q, k, v, causal=False, backend=backend,
                            block_q=64, block_k=64)
    assert jnp.max(jnp.abs(o - r)) < 2e-5


def test_flash_xla_differentiable():
    q, k, v = rand((2, 128, 32), k=1), rand((2, 128, 32), k=2), rand((2, 128, 32), k=3)

    def f(q):
        return jnp.sum(ops.flash_attention(q, k, v, backend="xla",
                                           block_q=64, block_k=64))

    def fr(q):
        return jnp.sum(ref.flash_attention_ref(q, k, v))
    g1, g2 = jax.grad(f)(q), jax.grad(fr)(q)
    assert jnp.max(jnp.abs(g1 - g2)) < 1e-4


# ---------------------------------------------------------- decode attn
@pytest.mark.parametrize("bh,s,d", [(6, 512, 64), (2, 2048, 128), (8, 256, 32)])
def test_decode_attention_ragged_lengths(bh, s, d):
    q, k, v = rand((bh, 1, d), k=1), rand((bh, s, d), k=2), rand((bh, s, d), k=3)
    lengths = (jnp.arange(bh) * (s // bh) + 1).astype(jnp.int32)
    r = ref.decode_attention_ref(q, k, v, lengths)
    o = ops.decode_attention(q, k, v, lengths, backend="interpret", block_k=128)
    assert jnp.max(jnp.abs(o - r)) < 2e-5


# --------------------------------------------------------------- mlstm
@pytest.mark.parametrize("bh,s,dk,dv", [(2, 256, 32, 32), (4, 128, 16, 64),
                                        (1, 512, 64, 64)])
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_mlstm_chunkwise_vs_recurrence(bh, s, dk, dv, backend):
    q, k = rand((bh, s, dk), k=1, scale=0.5), rand((bh, s, dk), k=2, scale=0.5)
    v = rand((bh, s, dv), k=3)
    logf = jax.nn.log_sigmoid(rand((bh, s), k=4) + 2.0)
    i = jax.nn.sigmoid(rand((bh, s), k=5))
    r = ref.mlstm_scan_ref(q, k, v, logf, i)
    o = ops.mlstm_scan(q, k, v, logf, i, backend=backend, chunk=64)
    assert jnp.max(jnp.abs(o - r)) < 1e-3


def test_mlstm_xla_differentiable():
    bh, s, d = 1, 128, 16
    q, k, v = rand((bh, s, d), k=1, scale=0.3), rand((bh, s, d), k=2, scale=0.3), rand((bh, s, d), k=3)
    logf = jax.nn.log_sigmoid(rand((bh, s), k=4) + 2.0)
    i = jax.nn.sigmoid(rand((bh, s), k=5))
    g = jax.grad(lambda v: jnp.sum(ops.mlstm_scan(q, k, v, logf, i,
                                                  backend="xla", chunk=32)))(v)
    assert jnp.all(jnp.isfinite(g))


# ------------------------------------------------------------ moe router
@pytest.mark.parametrize("t,e,k,n_valid", [(512, 64, 4, 60), (256, 256, 8, 256),
                                           (128, 16, 2, 16)])
def test_moe_topk_matches_ref(t, e, k, n_valid):
    logits = rand((t, e), k=1)
    rw, ri = ref.moe_topk_ref(logits, k, n_valid=n_valid)
    ow, oi = ops.moe_topk(logits, k, n_valid=n_valid, backend="interpret")
    assert jnp.all(ri == oi)
    assert jnp.max(jnp.abs(rw - ow)) < 1e-6


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 64), e=st.integers(4, 64), k=st.integers(1, 4),
       pad=st.integers(0, 3), seed=st.integers(0, 100))
def test_moe_router_invariants(t, e, k, pad, seed):
    """Property: weights sum to 1, indices unique per token and always
    inside the valid (non-padding) expert range."""
    k = min(k, e)
    n_valid = max(k, e - pad)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    w, idx = ref.moe_topk_ref(logits, k, n_valid=n_valid)
    assert jnp.allclose(jnp.sum(w, axis=-1), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < n_valid
    for row in idx:
        assert len(set(int(x) for x in row)) == k
