"""Live-mode kernel + serving engine integration (real threads, real JAX)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import Tier
from repro.core.live import LiveJob, LiveKernel, LiveLock
from repro.core.policies import make_policy
from repro.models.transformer import Model
from repro.serving.engine import InferenceEngine, Request


def test_live_two_tier_precedence():
    """While a TS job is runnable, the BG job gets (almost) no dispatches."""
    kernel = LiveKernel(1, make_policy("ufs"))
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
    counts = {"ts": 0, "bg": 0}

    def mk(name):
        def chunk(budget):
            counts[name] += 1
            time.sleep(0.002)
            return "yield"
        return chunk

    kernel.start()
    kernel.wake(LiveJob(bg, mk("bg"), name="bg"))
    kernel.wake(LiveJob(ts, mk("ts"), name="ts"))
    time.sleep(0.5)
    kernel.stop()
    assert counts["ts"] > 10
    assert counts["bg"] <= max(3, counts["ts"] // 10)


def test_live_lock_hint_boost():
    """A BG holder of a LiveLock gets boosted when a TS job reports waiting."""
    kernel = LiveKernel(1, make_policy("ufs"))
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
    lock = LiveLock(kernel, "shared")
    state = {"holder_done": False, "waiter_done": False}

    holder_job = LiveJob(bg, lambda b: "yield", name="holder")

    def holder_chunk(budget):
        if lock.holder is None and not state["holder_done"]:
            lock.acquire(holder_job)
            time.sleep(0.05)                      # work while holding
            lock.release(holder_job)
            state["holder_done"] = True
            return "done"
        return "yield"
    holder_job._run_chunk = holder_chunk

    waiter_job = LiveJob(ts, lambda b: "yield", name="waiter")

    def waiter_chunk(budget):
        if lock.acquire(waiter_job, timeout=5.0):
            lock.release(waiter_job)
            state["waiter_done"] = True
            return "done"
        return "yield"
    waiter_job._run_chunk = waiter_chunk

    kernel.start()
    kernel.wake(holder_job)
    time.sleep(0.01)
    kernel.wake(waiter_job)
    time.sleep(1.0)
    kernel.stop()
    assert state["holder_done"] and state["waiter_done"]
    assert kernel.hints.writes > 0


@pytest.mark.slow
def test_inference_engine_end_to_end():
    cfg = get_arch("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    kernel = LiveKernel(1, make_policy("ufs"))
    engine = InferenceEngine(model, params, kernel, max_batch=4, max_len=48)
    kernel.start()
    engine.start()
    rng = np.random.default_rng(0)
    reqs = [engine.submit(Request(prompt=rng.integers(0, cfg.vocab_size, 6)
                                  .astype(np.int32), max_new_tokens=4))
            for _ in range(3)]
    for r in reqs:
        assert r.done_event.wait(timeout=120), "request did not complete"
    engine.stop()
    kernel.stop()
    for r in reqs:
        assert len(r.tokens) >= 4
        assert r.latency is not None and r.latency > 0


@pytest.mark.slow
def test_engine_output_matches_direct_decode():
    """Engine greedy tokens == direct prefill+decode loop (cache pooling is
    transparent)."""
    cfg = get_arch("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = np.arange(1, 7, dtype=np.int32)
    # direct
    logits, caches = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, 48)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        lg, caches = model.decode_step(params, caches,
                                       jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    # engine
    kernel = LiveKernel(1, make_policy("ufs"))
    engine = InferenceEngine(model, params, kernel, max_batch=2, max_len=48)
    kernel.start()
    engine.start()
    r = engine.submit(Request(prompt=prompt, max_new_tokens=4))
    assert r.done_event.wait(timeout=120)
    engine.stop()
    kernel.stop()
    assert r.tokens[:4] == toks[:4]
