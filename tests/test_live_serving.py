"""Live-mode kernel + serving engine integration (real threads, real JAX)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import Tier
from repro.core.live import LiveJob, LiveKernel, LiveLock
from repro.core.policies import make_policy
from repro.core.task import JobState
from repro.models.transformer import Model
from repro.serving.engine import InferenceEngine, Request


def test_live_two_tier_precedence():
    """While a TS job is runnable, the BG job gets (almost) no dispatches."""
    kernel = LiveKernel(1, make_policy("ufs"))
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
    counts = {"ts": 0, "bg": 0}

    def mk(name):
        def chunk(budget):
            counts[name] += 1
            time.sleep(0.002)
            return "yield"
        return chunk

    kernel.start()
    kernel.wake(LiveJob(bg, mk("bg"), name="bg"))
    kernel.wake(LiveJob(ts, mk("ts"), name="ts"))
    time.sleep(0.5)
    kernel.stop()
    assert counts["ts"] > 10
    assert counts["bg"] <= max(3, counts["ts"] // 10)


def test_live_lock_hint_boost():
    """A BG holder of a LiveLock gets boosted when a TS job reports waiting."""
    kernel = LiveKernel(1, make_policy("ufs"))
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 1)
    lock = LiveLock(kernel, "shared")
    state = {"holder_done": False, "waiter_done": False}

    holder_job = LiveJob(bg, lambda b: "yield", name="holder")

    def holder_chunk(budget):
        if lock.holder is None and not state["holder_done"]:
            lock.acquire(holder_job)
            time.sleep(0.05)                      # work while holding
            lock.release(holder_job)
            state["holder_done"] = True
            return "done"
        return "yield"
    holder_job._run_chunk = holder_chunk

    waiter_job = LiveJob(ts, lambda b: "yield", name="waiter")

    def waiter_chunk(budget):
        if lock.acquire(waiter_job, timeout=5.0):
            lock.release(waiter_job)
            state["waiter_done"] = True
            return "done"
        return "yield"
    waiter_job._run_chunk = waiter_chunk

    kernel.start()
    kernel.wake(holder_job)
    time.sleep(0.01)
    kernel.wake(waiter_job)
    time.sleep(1.0)
    kernel.stop()
    assert state["holder_done"] and state["waiter_done"]
    assert kernel.hints.writes > 0


class _TinyModel:
    """Stub model with the engine's contract (init_cache / prefill /
    decode_step) but no weights: shutdown and deadline tests need the
    engine mechanics, not a real transformer."""

    vocab = 17

    def init_cache(self, batch, max_len):
        return {"k": jnp.zeros((batch, max_len), jnp.float32)}

    def _logits(self, batch, t):
        row = jnp.arange(self.vocab, dtype=jnp.float32)
        return jnp.tile(row[None, None, :], (batch, t, 1))

    def prefill(self, params, batch, max_len):
        toks = batch["tokens"]
        return (self._logits(1, toks.shape[1]),
                {"k": jnp.zeros((1, max_len), jnp.float32)})

    def decode_step(self, params, caches, toks, pos):
        return self._logits(toks.shape[0], 1), caches


def _tiny_engine(max_batch=2, max_len=64):
    kernel = LiveKernel(1, make_policy("ufs"))
    engine = InferenceEngine(_TinyModel(), None, kernel,
                             max_batch=max_batch, max_len=max_len)
    return kernel, engine


def test_engine_stop_wakes_blocked_decode_loop():
    """stop() must wake the parked decode loop so it exits; before the fix
    the loop slept forever and kernel.stop() left a zombie job."""
    kernel, engine = _tiny_engine()
    kernel.start()
    engine.start()
    # no requests: the first chunk parks the loop
    assert _wait_for(lambda: engine._job.state == JobState.BLOCKED)
    engine.stop()
    assert _wait_for(lambda: engine._job.state == JobState.EXITED), \
        "decode loop never observed the shutdown"
    kernel.stop()


def test_engine_stop_drains_pending_and_active():
    """stop(drain=True) fails everything in flight: done_event set,
    error='shutdown', cache slots back in the pool."""
    kernel, engine = _tiny_engine(max_batch=2, max_len=4096)
    kernel.start()
    engine.start()
    rng = np.random.default_rng(0)
    reqs = [engine.submit(Request(
        prompt=rng.integers(0, 17, 4).astype(np.int32),
        max_new_tokens=100_000)) for _ in range(3)]
    # 2 admitted into slots, 1 pending; none can finish before max_len
    assert _wait_for(lambda: len(engine.active) == 2)
    engine.stop()
    for r in reqs:
        assert r.done_event.wait(timeout=5), "request leaked at shutdown"
        assert r.error == "shutdown" and not r.ok
    assert not engine.pending and not engine.active
    assert sorted(engine.pool.free) == [0, 1]
    assert _wait_for(lambda: engine._job.state == JobState.EXITED)
    kernel.stop()


def test_engine_request_deadline_fails_and_frees_slot():
    kernel, engine = _tiny_engine(max_batch=2, max_len=4096)
    kernel.start()
    engine.start()
    rng = np.random.default_rng(0)
    doomed = engine.submit(Request(
        prompt=rng.integers(0, 17, 4).astype(np.int32),
        max_new_tokens=100_000, deadline_s=0.05))
    assert doomed.done_event.wait(timeout=10), "deadline never enforced"
    assert doomed.error == "deadline" and not doomed.ok
    # its cache slot went back to the pool and a fresh request still works
    ok = engine.submit(Request(
        prompt=rng.integers(0, 17, 4).astype(np.int32), max_new_tokens=3))
    assert ok.done_event.wait(timeout=30)
    assert ok.ok and len(ok.tokens) >= 3
    engine.stop()
    kernel.stop()


def _wait_for(cond, timeout=5.0, interval=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


@pytest.mark.slow
def test_inference_engine_end_to_end():
    cfg = get_arch("qwen2-0.5b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    kernel = LiveKernel(1, make_policy("ufs"))
    engine = InferenceEngine(model, params, kernel, max_batch=4, max_len=48)
    kernel.start()
    engine.start()
    rng = np.random.default_rng(0)
    reqs = [engine.submit(Request(prompt=rng.integers(0, cfg.vocab_size, 6)
                                  .astype(np.int32), max_new_tokens=4))
            for _ in range(3)]
    for r in reqs:
        assert r.done_event.wait(timeout=120), "request did not complete"
    engine.stop()
    kernel.stop()
    for r in reqs:
        assert len(r.tokens) >= 4
        assert r.latency is not None and r.latency > 0


@pytest.mark.slow
def test_engine_output_matches_direct_decode():
    """Engine greedy tokens == direct prefill+decode loop (cache pooling is
    transparent)."""
    cfg = get_arch("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = np.arange(1, 7, dtype=np.int32)
    # direct
    logits, caches = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, 48)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        lg, caches = model.decode_step(params, caches,
                                       jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    # engine
    kernel = LiveKernel(1, make_policy("ufs"))
    engine = InferenceEngine(model, params, kernel, max_batch=2, max_len=48)
    kernel.start()
    engine.start()
    r = engine.submit(Request(prompt=prompt, max_new_tokens=4))
    assert r.done_event.wait(timeout=120)
    engine.stop()
    kernel.stop()
    assert r.tokens[:4] == toks[:4]
