"""Metrics regressions: record_run window clamping and the consolidated
``Metrics.summary`` read surface.

The clamping bug: the old one-sided ``min(t, window_end)`` could place the
clipped end *before* the clipped start for a run landing entirely past
``window_end``, and a run straddling the end edge was charged for its
out-of-window tail.  Both ends must clamp symmetrically into
``[window_start, window_end]``.
"""
import math

import pytest

from repro.core.metrics import Metrics


def _m(start=1.0, end=2.0):
    m = Metrics()
    m.window_start, m.window_end = start, end
    return m


# ---------------------------------------------------------------------------
# record_run clamping
# ---------------------------------------------------------------------------

def test_run_inside_window_charged_fully():
    m = _m()
    m.record_run(0, "bursty", "ts", dur=0.4, t=1.8)
    assert m.slot_busy[(0, "bursty")] == pytest.approx(0.4)
    assert m.cpu_by_group["ts"] == pytest.approx(0.4)


def test_run_straddling_window_start_clipped():
    m = _m()
    m.record_run(0, "bursty", "ts", dur=1.0, t=1.5)     # spans 0.5..1.5
    assert m.slot_busy[(0, "bursty")] == pytest.approx(0.5)


def test_run_straddling_window_end_clipped():
    m = _m()
    m.record_run(0, "bound", "bg", dur=1.0, t=2.5)      # spans 1.5..2.5
    assert m.slot_busy[(0, "bound")] == pytest.approx(0.5)


def test_run_entirely_after_window_end_contributes_nothing():
    """The regression case: hi clamps to window_end and lo used to stay at
    t - dur > window_end, yielding a negative span."""
    m = _m()
    m.record_run(0, "bound", "bg", dur=1.0, t=5.0)      # spans 4.0..5.0
    assert (0, "bound") not in m.slot_busy
    assert "bg" not in m.cpu_by_group


def test_run_entirely_before_window_start_contributes_nothing():
    m = _m()
    m.record_run(0, "bursty", "ts", dur=0.3, t=0.5)
    assert (0, "bursty") not in m.slot_busy


def test_run_spanning_whole_window_charged_window_only():
    m = _m()
    m.record_run(1, "bound", "bg", dur=10.0, t=5.0)     # spans -5..5
    assert m.slot_busy[(1, "bound")] == pytest.approx(1.0)   # exactly the window


def test_open_window_end_means_no_upper_clamp():
    m = _m(start=1.0, end=0.0)                          # end=0 -> open window
    m.record_run(0, "bursty", "ts", dur=1.0, t=50.0)
    assert m.slot_busy[(0, "bursty")] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# summary surface
# ---------------------------------------------------------------------------

def test_summary_structure_and_values():
    m = _m(start=0.0, end=2.0)
    m.record_run(0, "bursty", "ts", dur=0.5, t=1.0)
    m.record_run(1, "bound", "bg", dur=1.0, t=2.0)
    m.record_request("ts", latency=0.003, t=1.0)
    m.record_request("ts", latency=0.005, t=1.5)
    m.record_wakeup("ts", delay=0.001, t=1.0)
    m.preemptions, m.kicks, m.dispatches = 3, 4, 5

    s = m.summary(n_slots=2)
    assert s["window"] == {"start": 0.0, "end": 2.0, "duration": 2.0}
    assert s["counters"]["preemptions"] == 3
    assert s["counters"]["kicks"] == 4
    assert s["counters"]["dispatches"] == 5
    ts = s["groups"]["ts"]
    assert ts["completed"] == 2
    assert ts["throughput"] == pytest.approx(1.0)       # 2 requests / 2 s
    assert ts["cpu_s"] == pytest.approx(0.5)
    assert ts["latency"]["n"] == 2
    assert ts["latency"]["mean"] == pytest.approx(0.004)
    assert ts["wakeup"]["n"] == 1
    assert ts["wakeup"]["max"] == pytest.approx(0.001)
    # bg saw CPU but no requests: present, with NaN latency markers.
    assert s["groups"]["bg"]["completed"] == 0
    assert math.isnan(s["groups"]["bg"]["latency"]["mean"])
    assert s["slots"]["n"] == 2
    assert s["slots"]["busy_by_kind"]["bursty"] == [pytest.approx(0.5), 0.0]
    assert s["slots"]["busy_by_kind"]["bound"] == [0.0, pytest.approx(1.0)]
    assert s["slots"]["skew_by_kind"]["bursty"] == pytest.approx(2.0)


def test_summary_explicit_groups_includes_idle():
    m = _m(start=0.0, end=1.0)
    s = m.summary(groups=["quiet"])
    assert s["groups"]["quiet"]["completed"] == 0
    assert s["groups"]["quiet"]["throughput"] == 0.0
    # No n_slots -> no slots block.
    assert "slots" not in s
