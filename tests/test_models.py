"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness assertions) and prefill->decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, get_arch
from repro.models.transformer import Model, build_plan

KEY = jax.random.PRNGKey(0)
B, S, SMAX = 2, 24, 48


def make_batch(cfg, toks):
    batch = {"tokens": toks}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            KEY, (toks.shape[0], cfg.encoder_len, cfg.d_model))
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            KEY, (toks.shape[0], cfg.vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", all_archs())
def test_arch_train_step_smoke(name):
    cfg = get_arch(name).reduced()
    m = Model(cfg)
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = make_batch(cfg, toks)
    batch["labels"] = toks
    loss, metrics = jax.jit(m.train_loss)(params, batch)
    assert jnp.isfinite(loss)
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("name", all_archs())
def test_arch_prefill_decode_smoke(name):
    cfg = get_arch(name).reduced()
    m = Model(cfg)
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits, caches = m.prefill(params, make_batch(cfg, toks), SMAX)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lg, caches = m.decode_step(params, caches, tok, S)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(lg))


@pytest.mark.parametrize("name", ["llama3.2-1b", "qwen2-0.5b", "hymba-1.5b",
                                  "xlstm-350m", "deepseek-v3-671b",
                                  "qwen2-moe-a2.7b", "seamless-m4t-medium"])
def test_decode_matches_full_forward(name):
    """Token-S logits from (prefill S -> decode) must equal the full
    (S+1)-token forward -- exercises every cache variant."""
    cfg = get_arch(name).reduced()
    e = cfg.moe.routed_total() if cfg.moe else 1
    m = Model(cfg, capacity_factor=float(e))     # drop-free MoE for equality
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    batch, batch_full = make_batch(cfg, toks[:, :S]), make_batch(cfg, toks)
    _, caches = m.prefill(params, batch, SMAX)
    lg_dec, _ = m.decode_step(params, caches, toks[:, S:S + 1], S)
    lg_full, _ = m.prefill(params, batch_full, SMAX + 1)
    rel = float(jnp.max(jnp.abs(lg_dec - lg_full))) / \
        (float(jnp.max(jnp.abs(lg_full))) + 1e-9)
    assert rel < 2e-2


@pytest.mark.slow
def test_sliding_window_cache_is_ring():
    """Hymba SWA decode must agree with full forward past the window."""
    cfg = get_arch("hymba-1.5b").reduced()
    m = Model(cfg)
    params = m.init_params(KEY)
    n = cfg.sliding_window + 10              # force wraparound
    toks = jax.random.randint(KEY, (1, n + 1), 0, cfg.vocab_size)
    _, caches = m.prefill(params, {"tokens": toks[:, :n]}, n + 8)
    lg_dec, _ = m.decode_step(params, caches, toks[:, n:n + 1], n)
    lg_full, _ = m.prefill(params, {"tokens": toks}, n + 9)
    rel = float(jnp.max(jnp.abs(lg_dec - lg_full))) / \
        (float(jnp.max(jnp.abs(lg_full))) + 1e-9)
    assert rel < 2e-2


def test_plan_layer_counts():
    for name in all_archs():
        cfg = get_arch(name)
        plan = build_plan(cfg)
        assert sum(s.n for s in plan) == cfg.n_layers, name


def test_unrolled_matches_scan():
    cfg = get_arch("llama3.2-1b").reduced()
    params = Model(cfg).init_params(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = Model(cfg).train_loss(params, batch)
    l2, _ = Model(cfg, unroll=True).train_loss(params, batch)
    assert jnp.allclose(l1, l2, atol=1e-5)


def test_moe_aux_loss_nonzero_and_capacity_drops():
    cfg = get_arch("qwen2-moe-a2.7b").reduced()
    m = Model(cfg)
    params = m.init_params(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    loss, metrics = m.train_loss(params, {"tokens": toks, "labels": toks})
    assert float(metrics["aux"]) > 0.0
