"""Sim/live executor parity plus regressions for the unified SchedCore.

The same ``UFSPolicy`` class drives both backends; these tests pin down the
behaviour that must not diverge between them (DESIGN.md section 7):
preemptions happen only under TS/BG contention, the background tier never
starts while time-sensitive work sits queued, and the TS class wins the CPU
share. Also covers the affinity-mask fallback and the live concurrent
hint-boost path (which used to crash inside the old LiveKernel lock shim).
"""
import threading
import time

import pytest

from repro.core import Job, SchedKernel, Tier
from repro.core.live import LiveJob, LiveKernel, LiveLock
from repro.core.task import JobState
from repro.core.ufs import UFSPolicy
from repro.core.workloads import bound_worker, bursty_worker


class RecordingUFS(UFSPolicy):
    """UFS that counts background starts made while TS work was queued."""

    def __init__(self):
        super().__init__()
        self.bg_starts = 0
        self.violations = 0

    def running(self, job, slot):
        if job.tier == Tier.BACKGROUND:
            self.bg_starts += 1
            for s in self.kernel.slots:
                if any(q.state == JobState.RUNNABLE
                       and q.tier == Tier.TIME_SENSITIVE
                       for q in s.local_dsq.jobs()):
                    self.violations += 1
                    break
        super().running(job, slot)


def _sim_mix(mixed: bool):
    pol = RecordingUFS()
    k = SchedKernel(1, pol, seed=3)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    k.add_job(Job(ts, behavior=bursty_worker(1), name="ts0", kind="bursty"))
    if mixed:
        k.add_job(Job(bg, behavior=bound_worker(2, query_cpu=0.05),
                      name="bg0", kind="bound"))
    m = k.run(2.0)
    return pol, m


def _live_mix(mixed: bool):
    pol = RecordingUFS()
    k = LiveKernel(1, pol)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)

    def ts_chunk(budget):
        time.sleep(0.002)
        return "blocked"

    def bg_chunk(budget):
        time.sleep(0.002)
        return "yield"

    tsj = LiveJob(ts, ts_chunk, name="ts0", kind="bursty")
    stop = threading.Event()

    def waker():
        while not stop.is_set():
            time.sleep(0.005)
            if tsj.state == JobState.BLOCKED:
                k.wake(tsj)

    k.start()
    k.wake(tsj)
    if mixed:
        k.wake(LiveJob(bg, bg_chunk, name="bg0", kind="bound"))
    wt = threading.Thread(target=waker, daemon=True)
    wt.start()
    time.sleep(0.5)
    stop.set()
    wt.join()
    k.stop()
    return pol, k.metrics


def test_sim_live_parity_preemption_ordering():
    """Both executors: preemptions only under contention, none solo, and the
    background tier never dispatches ahead of queued TS work."""
    sim_pol, sim_m = _sim_mix(mixed=True)
    _, sim_solo = _sim_mix(mixed=False)
    live_pol, live_m = _live_mix(mixed=True)
    _, live_solo = _live_mix(mixed=False)

    assert sim_m.preemptions > 0 and sim_solo.preemptions == 0
    assert live_m.preemptions > 0 and live_solo.preemptions == 0
    # The invariant itself: BG must have run (the workload is mixed) but
    # never while a runnable TS job sat in a local DSQ. Live threads give
    # the check a one-race tolerance (wake can land mid-dispatch).
    assert sim_pol.bg_starts > 0 and sim_pol.violations == 0
    assert live_pol.bg_starts > 0 and live_pol.violations <= 1
    # And the TS class keeps its full demand on both backends: its CPU
    # share must be at least its solo duty cycle (~29% live, ~60% sim).
    for m, floor in ((sim_m, 0.5), (live_m, 0.2)):
        total = m.cpu_by_group["ts"] + m.cpu_by_group["bg"]
        assert total > 0 and m.cpu_by_group["ts"] / total > floor


def test_ufs_affinity_empty_fallback():
    """A slot_affinity mask matching no online slot must fall back to the
    full online set instead of crashing placement (used to IndexError)."""
    k = SchedKernel(2, UFSPolicy(), seed=1)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000,
                        slot_affinity=frozenset({99}))
    k.add_job(Job(ts, behavior=bursty_worker(1), name="t", kind="bursty"))
    m = k.run(0.05)
    assert m.cpu_by_group["ts"] > 0


def test_live_concurrent_hint_boost_two_slots():
    """Boost delivery while the holder is mid-chunk on another slot: the old
    LiveKernel lock shim raised AttributeError (RLock.locked) on exactly
    this path; the ThreadExecutor guard must survive it and both jobs must
    finish."""
    pol = UFSPolicy()
    k = LiveKernel(2, pol)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = LiveLock(k, "shared")
    state = {"holder_done": False, "waiter_done": False}

    holder_job = LiveJob(bg, lambda b: "yield", name="holder")

    def holder_chunk(budget):
        if lock.holder is None and not state["holder_done"]:
            lock.acquire(holder_job)
            time.sleep(0.08)                 # long chunk: waiter overlaps
            lock.release(holder_job)
            state["holder_done"] = True
            return "done"
        return "yield"
    holder_job._run_chunk = holder_chunk

    waiter_job = LiveJob(ts, lambda b: "yield", name="waiter")

    def waiter_chunk(budget):
        if lock.acquire(waiter_job, timeout=5.0):
            lock.release(waiter_job)
            state["waiter_done"] = True
            return "done"
        return "yield"
    waiter_job._run_chunk = waiter_chunk

    k.start()
    k.wake(holder_job)
    time.sleep(0.02)                         # holder is now mid-chunk
    k.wake(waiter_job)                       # runs on slot 2, hits the lock
    deadline = time.monotonic() + 5.0
    while (not (state["holder_done"] and state["waiter_done"])
           and time.monotonic() < deadline):
        time.sleep(0.01)
    k.stop()
    assert state["holder_done"] and state["waiter_done"]
    assert k.hints.boosts >= 1               # the wait actually boosted
