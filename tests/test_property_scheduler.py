"""Property-based tests (hypothesis) on scheduler invariants."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import Job, SchedKernel, Tier, make_policy
from repro.core.runnable_tree import RunnableTree
from repro.core.task import WorkloadGroup
from repro.core.workloads import bound_worker


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=100.0), min_size=1,
                max_size=24),
       st.data())
def test_runnable_tree_always_returns_min(vrts, data):
    """peek_min == min over live members, under arbitrary insert/remove/rekey."""
    tree = RunnableTree()
    groups = []
    for i, v in enumerate(vrts):
        g = WorkloadGroup(f"g{i}", Tier.BACKGROUND)
        g.vruntime = v
        tree.insert(g)
        groups.append(g)
    live = dict.fromkeys(range(len(groups)))
    for _ in range(min(30, 3 * len(groups))):
        op = data.draw(st.sampled_from(["remove", "rekey", "peek"]))
        if op == "remove" and live:
            i = data.draw(st.sampled_from(sorted(live)))
            tree.remove(groups[i])
            del live[i]
        elif op == "rekey" and live:
            i = data.draw(st.sampled_from(sorted(live)))
            groups[i].vruntime = data.draw(
                st.floats(min_value=0.0, max_value=100.0))
            tree.insert(groups[i])
        got = tree.peek_min()
        if not live:
            assert got is None
        else:
            expect = min(groups[i].vruntime for i in live)
            assert got.vruntime == expect


@settings(max_examples=10, deadline=None)
@given(n_slots=st.integers(min_value=1, max_value=4),
       n_jobs=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=999))
def test_work_conservation(n_slots, n_jobs, seed):
    """With CPU-bound jobs >= 1, no capacity is wasted while work is
    runnable: total busy time == min(n_jobs, n_slots) * horizon."""
    k = SchedKernel(n_slots, make_policy("ufs"))
    g = k.create_group("bg", Tier.BACKGROUND, 100)
    for i in range(n_jobs):
        k.add_job(Job(g, behavior=bound_worker(seed + i, query_cpu=1e6),
                      kind="bound"))
    horizon = 2.0
    m = k.run(horizon)
    busy = sum(m.slot_busy.values())
    expect = min(n_jobs, n_slots) * horizon
    assert abs(busy - expect) < 0.05 * expect + 0.01


@settings(max_examples=10, deadline=None)
@given(w1=st.integers(min_value=100, max_value=10000),
       w2=st.integers(min_value=100, max_value=10000))
def test_bg_proportional_share_tracks_weights(w1, w2):
    """Two saturating background groups split capacity ~ proportional to
    weight (cgroup cpu.weight semantics) under tree dispatch."""
    k = SchedKernel(1, make_policy("ufs"))
    g1 = k.create_group("g1", Tier.BACKGROUND, w1)
    g2 = k.create_group("g2", Tier.BACKGROUND, w2)
    k.add_job(Job(g1, behavior=bound_worker(1, query_cpu=1e6)))
    k.add_job(Job(g2, behavior=bound_worker(2, query_cpu=1e6)))
    k.run(5.0)
    share = g1.usage_time / max(g2.usage_time, 1e-9)
    expect = w1 / w2
    assert 0.6 * expect < share < 1.6 * expect


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=99))
def test_ts_always_beats_bg_for_cpu(seed):
    """Strict tier precedence: a saturating TS job squeezes BG to ~zero."""
    k = SchedKernel(1, make_policy("ufs"))
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = k.create_group("bg", Tier.BACKGROUND, 10000)   # weight cannot help
    k.add_job(Job(ts, behavior=bound_worker(seed, query_cpu=1e6)))
    k.add_job(Job(bg, behavior=bound_worker(seed + 1, query_cpu=1e6)))
    k.run(2.0)
    assert bg.usage_time < 0.02
    assert ts.usage_time > 1.95
