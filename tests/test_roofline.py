"""Roofline machinery: HLO collective parsing, analytic FLOPs/memory."""
import jax
import pytest

from repro.configs.base import SHAPES, get_arch
from repro.roofline import analysis as RA

HLO = """
HloModule jit_step
%all-gather.3 = bf16[4,1024,512]{2,1,0} all-gather(%param.1), dimensions={0}
%ar = f32[2048]{0} all-reduce(%x), to_apply=%add
%rs.1 = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
%ag.s = bf16[128,16]{1,0} all-gather-start(%p), dimensions={0}
%ag.d = bf16[128,16]{1,0} all-gather-done(%ag.s)
%cp = u8[1024]{0} collective-permute(%y), source_target_pairs={{0,1}}
%dot.5 = f32[128,128]{1,0} dot(%l, %r)
"""


def test_collective_parser_sums_and_dedups():
    out = RA.collective_bytes(HLO)
    ag = 4 * 1024 * 512 * 2 + 128 * 16 * 2       # all-gather + -start (done skipped)
    assert out["all-gather"] == ag
    assert out["all-reduce"] == 2048 * 4
    assert out["reduce-scatter"] == 64 * 4 * 2   # tuple result
    assert out["collective-permute"] == 1024
    assert out["all-to-all"] == 0
    assert out["total"] == ag + 2048 * 4 + 512 + 1024
    assert out["counts"]["all-gather"] == 2


def test_model_flops_moe_counts_active_only():
    cfg = get_arch("qwen2-moe-a2.7b")
    shape = SHAPES["train_4k"]
    import jax.numpy as jnp
    from repro.models.transformer import Model
    params = jax.eval_shape(lambda: Model(cfg).init_params(jax.random.PRNGKey(0)))
    total = RA.count_params(params)
    active = RA.active_params(cfg, total)
    assert active < 0.45 * total                 # 60 routed -> top-4 active
    f_train = RA.model_flops(cfg, shape, total, 256)
    f_prefill = RA.model_flops(cfg, SHAPES["prefill_32k"], total, 256)
    assert f_train > 0 and f_prefill > 0


def test_decode_flops_dominated_by_attention_and_head():
    cfg = get_arch("llama3.2-1b")
    shape = SHAPES["decode_32k"]
    attn = RA.attn_model_flops(cfg, shape, 256)
    total = RA.model_flops(cfg, shape, 1_240_000_000, 256)
    assert attn > 0.3 * total                    # 32k context reads dominate


def test_roofline_bottleneck_selection():
    r = RA.Roofline(flops=1e12, hbm_bytes=1e9, coll_bytes=1e12,
                    model_flops=5e11)
    assert r.bottleneck == "collective"
    assert r.t_collective == pytest.approx(20.0)
    assert 0 < r.mfu_bound < 1


def test_analytic_memory_decode_is_residents():
    cfg = get_arch("stablelm-3b")
    shape = SHAPES["decode_32k"]
    mem = RA.analytic_memory_bytes(cfg, shape, arg_bytes=5e9, out_bytes=5e9,
                                   n_devices=256)
    assert 5e9 <= mem < 5.1e9                    # cache read once + tiny writes
