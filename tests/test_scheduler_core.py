"""Unit tests: vruntime accounting, runnable tree, DSQs, kernel mechanics,
UFS policy behaviours (tiers, preemption, proportionality, caps, affinity),
elasticity."""
import pytest

from repro.core import (Job, JobState, SchedKernel, Tier, UFSPolicy,
                        WorkloadGroup, make_policy)
from repro.core import vruntime as vrt
from repro.core.runnable_tree import RunnableTree
from repro.core.task import Block, Burst, Exit, RequestBegin, RequestEnd
from repro.core.workloads import bound_worker, bursty_worker


def mk_kernel(n_slots=2, policy="ufs", **kw):
    return SchedKernel(n_slots, make_policy(policy), **kw)


# ---------------------------------------------------------------- vruntime
def test_weight_scaled_charging():
    g = WorkloadGroup("g", Tier.TIME_SENSITIVE, weight=200.0)
    j = Job(g, behavior=iter(()))
    vd = vrt.charge_task(j, 1.0)
    assert vd == pytest.approx(0.5)          # weight 200 -> half the vruntime
    assert j.total_cpu == 1.0


def test_hierarchical_effective_weight():
    root = WorkloadGroup("root", Tier.BACKGROUND, weight=100.0)
    a = WorkloadGroup("a", Tier.BACKGROUND, weight=300.0, parent=root)
    b = WorkloadGroup("b", Tier.BACKGROUND, weight=100.0, parent=root)
    assert a.effective_weight() == pytest.approx(75.0)
    assert b.effective_weight() == pytest.approx(25.0)
    b.set_weight(300.0)
    assert a.effective_weight() == pytest.approx(50.0)


def test_tier_mismatch_rejected():
    root = WorkloadGroup("root", Tier.BACKGROUND)
    with pytest.raises(ValueError):
        WorkloadGroup("c", Tier.TIME_SENSITIVE, parent=root)


def test_clamp_prevents_credit_hoarding():
    g = WorkloadGroup("g", Tier.TIME_SENSITIVE, weight=100.0)
    g.task_vmax = 10.0
    j = Job(g, behavior=iter(()))
    j.vruntime = 0.0                         # long idle
    vrt.clamp_task_vruntime(j, 0.003)
    assert j.vruntime == pytest.approx(10.0 - 0.003)


# ------------------------------------------------------------ runnable tree
def test_runnable_tree_min_and_rekey():
    t = RunnableTree()
    gs = [WorkloadGroup(f"g{i}", Tier.BACKGROUND) for i in range(4)]
    for i, g in enumerate(gs):
        g.vruntime = float(i)
        t.insert(g)
    assert t.peek_min() is gs[0]
    gs[0].vruntime = 9.0
    t.insert(gs[0])                          # re-key
    assert t.peek_min() is gs[1]
    t.remove(gs[1])
    assert t.peek_min() is gs[2]
    assert len(t) == 3


# ----------------------------------------------------------------- kernel
def test_slice_expiry_round_robins_equal_jobs():
    k = mk_kernel(1)
    g = k.create_group("bg", Tier.BACKGROUND, 100)
    j1 = Job(g, behavior=bound_worker(1, query_cpu=0.5), name="a", kind="bound")
    j2 = Job(g, behavior=bound_worker(2, query_cpu=0.5), name="b", kind="bound")
    k.add_job(j1), k.add_job(j2)
    k.run(1.0)
    # both make progress interleaved by slices
    assert j1.total_cpu > 0.3 and j2.total_cpu > 0.3
    assert abs(j1.total_cpu - j2.total_cpu) < 0.1


def test_two_tier_strict_precedence():
    """Background runs ONLY when no time-sensitive work wants the slot."""
    k = mk_kernel(1)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    jts = Job(ts, behavior=bound_worker(1, query_cpu=10.0), kind="bound")
    jbg = Job(bg, behavior=bound_worker(2, query_cpu=10.0), kind="bound")
    k.add_job(jbg)
    k.add_job(jts, at=0.1)                  # arrives while BG running
    k.run(1.0)
    assert jbg.total_cpu == pytest.approx(0.1, abs=0.01)   # preempted at once
    assert jts.total_cpu == pytest.approx(0.9, abs=0.01)


def test_preemption_kick_is_immediate():
    k = mk_kernel(1)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    k.add_job(Job(bg, behavior=bound_worker(1, query_cpu=10.0)))
    k.add_job(Job(ts, behavior=bursty_worker(2)), at=0.05)
    m = k.run(0.5)
    assert m.preemptions >= 1
    assert m.latency_stats("ts")["mean"] < 4e-3   # near-solo latency


def test_kick_latency_models_chunk_boundary():
    """TPU adaptation: preemption takes effect at the chunk boundary."""
    k = mk_kernel(1, kick_latency=0.02)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    k.add_job(Job(bg, behavior=bound_worker(1, query_cpu=10.0)))
    k.add_job(Job(ts, behavior=bursty_worker(2)), at=0.1)
    m = k.run(1.1)
    # latency now includes ~kick_latency of waiting
    assert m.latency_stats("ts")["mean"] > 3e-3


def test_bg_weight_proportionality():
    """Runnable-tree dispatch shares slots proportional to group weight."""
    k = mk_kernel(2)
    g1 = k.create_group("g1", Tier.BACKGROUND, 200)
    g2 = k.create_group("g2", Tier.BACKGROUND, 100)
    for i in range(2):
        k.add_job(Job(g1, behavior=bound_worker(i, query_cpu=100.0), kind="bound"))
        k.add_job(Job(g2, behavior=bound_worker(10 + i, query_cpu=100.0), kind="bound"))
    k.run(10.0)
    ratio = g1.usage_time / g2.usage_time
    assert 1.7 < ratio < 2.4


def test_ts_weight_proportionality():
    """Figure 8: weight-proportional sharing within the TS tier."""
    k = mk_kernel(2)
    g1 = k.create_group("hi", Tier.TIME_SENSITIVE, 10000)
    g2 = k.create_group("lo", Tier.TIME_SENSITIVE, 6670)
    for i in range(2):
        k.add_job(Job(g1, behavior=bound_worker(i, query_cpu=100.0), kind="bound"))
        k.add_job(Job(g2, behavior=bound_worker(10 + i, query_cpu=100.0), kind="bound"))
    k.run(10.0)
    ratio = g1.usage_time / g2.usage_time
    assert 1.25 < ratio < 1.8                # expect ~10000/6670 = 1.5


def test_rate_cap():
    k = mk_kernel(1)
    g = k.create_group("capped", Tier.BACKGROUND, 100, rate_cap=0.25)
    k.add_job(Job(g, behavior=bound_worker(1, query_cpu=100.0)))
    k.run(4.0)
    assert g.usage_time <= 0.3 * 4.0


def test_slot_affinity():
    k = mk_kernel(2)
    g = k.create_group("pin0", Tier.BACKGROUND, 100,
                       slot_affinity=frozenset({0}))
    k.add_job(Job(g, behavior=bound_worker(1, query_cpu=100.0), kind="bound"))
    m = k.run(2.0)
    assert m.slot_busy.get((0, "bound"), 0.0) > 1.5
    assert m.slot_busy.get((1, "bound"), 0.0) == 0.0


def test_drain_slot_requeues_work():
    k = mk_kernel(2)
    g = k.create_group("bg", Tier.BACKGROUND, 100)
    jobs = [Job(g, behavior=bound_worker(i, query_cpu=100.0), kind="bound")
            for i in range(2)]
    for j in jobs:
        k.add_job(j)
    k.clock.at(1.0, lambda: k.drain_slot(1))
    k.run(3.0)
    busy1 = k.metrics.slot_busy.get((1, "bound"), 0.0)
    assert busy1 <= 1.05                     # nothing after the drain
    assert all(j.total_cpu > 0.5 for j in jobs)   # both kept running on slot 0


def test_add_slot_elastic_scale_up():
    k = mk_kernel(1)
    g = k.create_group("bg", Tier.BACKGROUND, 100)
    for i in range(2):
        k.add_job(Job(g, behavior=bound_worker(i, query_cpu=100.0), kind="bound"))
    k.clock.at(1.0, lambda: k.add_slot())
    k.run(3.0)
    total = sum(v for kk, v in k.metrics.slot_busy.items())
    assert total > 1.0 + 1.9                  # ~1 slot-sec then ~2/sec


def test_exit_releases_locks():
    k = mk_kernel(1)
    g = k.create_group("bg", Tier.BACKGROUND, 100)
    lock = k.create_lock()

    def holder():
        yield Burst(0.01)
        from repro.core.locks import spin_acquire
        yield from spin_acquire(lock)
        yield Exit()

    j = Job(g, behavior=holder())
    k.add_job(j)
    k.run(1.0)
    assert lock.holder is None
