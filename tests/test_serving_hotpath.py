"""Serving hot path: overlapped decode, batched admission, event dispatch.

Covers the PR-10 overhaul: the generation-counter snapshot/merge decode,
batched admission prefill (padding exactness on the stub model), bulk
prefill vs decode under slot exhaustion, drain racing an in-flight bulk
prefill, the event-driven ThreadExecutor (park/unpark, settle wait, thread
reaping, timer pruning) and the legacy compatibility modes the serving
benchmark uses as its baseline.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Tier
from repro.core.live import LiveJob, LiveKernel
from repro.core.policies import make_policy
from repro.core.task import JobState
from repro.core.trace import SchedTracer, validate_events, wakeup_delays
from repro.serving.engine import InferenceEngine, Request
from repro.serving.kv_cache import cache_batch_axes, make_write_slots
from repro.serving.stub import TinyStubModel


def _wait_for(cond, timeout=5.0, dt=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(dt)
    return cond()


def _stub_engine(max_batch=4, max_len=64, n_slots=2, **engine_kw):
    model = TinyStubModel()
    params = model.init_params(0)
    kernel = LiveKernel(n_slots, make_policy("ufs"),
                        **engine_kw.pop("kernel_kw", {}))
    engine = InferenceEngine(model, params, kernel,
                             max_batch=max_batch, max_len=max_len,
                             **engine_kw)
    return model, params, kernel, engine


def _direct_greedy(model, params, prompt, n_tokens, max_len=64):
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}, max_len)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < n_tokens:
        lg, caches = model.decode_step(
            params, caches, jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


# --------------------------------------------------------- model-level exact
def test_stub_batched_prefill_matches_single():
    """Right-padded batched prefill must equal per-request prefill exactly:
    the stub gathers each row's recurrent state at lengths-1, so the padded
    tail never touches it."""
    model = TinyStubModel()
    params = model.init_params(3)
    prompts = [np.arange(1, 1 + n, dtype=np.int32) for n in (3, 5, 2)]
    L = 8
    toks = np.zeros((3, L), np.int32)
    lengths = np.zeros((3,), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        lengths[i] = len(p)
    blogits, bcache = model.prefill_batch(
        params, {"tokens": jnp.asarray(toks),
                 "lengths": jnp.asarray(lengths)}, 64)
    for i, p in enumerate(prompts):
        slogits, scache = model.prefill(
            params, {"tokens": jnp.asarray(p[None, :])}, 64)
        np.testing.assert_allclose(np.asarray(blogits[i, 0]),
                                   np.asarray(slogits[0, -1]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(bcache["h"][i]),
                                   np.asarray(scache["h"][0]),
                                   rtol=1e-5, atol=1e-6)


def test_write_slots_drops_sentinel_rows():
    """Out-of-range slot indices (the padding sentinel = pool size) must be
    dropped, not wrapped: -1 would silently clobber the last pool row."""
    model = TinyStubModel(d_model=4)
    axes = cache_batch_axes(model, 16)
    write = make_write_slots(axes)
    pool = {"h": jnp.zeros((4, 4), jnp.float32)}
    rows = {"h": jnp.ones((2, 4), jnp.float32)}
    out = write(pool, rows, jnp.asarray([1, 4], jnp.int32))
    got = np.asarray(out["h"])
    assert got[1].tolist() == [1.0] * 4
    for r in (0, 2, 3):
        assert got[r].tolist() == [0.0] * 4, f"row {r} clobbered by sentinel"


# ------------------------------------------------------- engine end-to-end
def test_hotpath_engine_matches_direct_decode():
    """Overlapped decode + batched admission produce the same greedy tokens
    as a direct unscheduled prefill+decode loop, across a ragged batch."""
    model, params, kernel, engine = _stub_engine(max_batch=4)
    kernel.start()
    engine.start()
    prompts = [np.arange(1, 1 + n, dtype=np.int32) for n in (4, 7, 2)]
    reqs = [engine.submit(Request(prompt=p, max_new_tokens=6))
            for p in prompts]
    for r in reqs:
        assert r.done_event.wait(timeout=30)
        assert r.ok
    engine.stop()
    kernel.stop()
    for p, r in zip(prompts, reqs):
        assert r.tokens == _direct_greedy(model, params, p, 6)
    assert engine.stats.decode_steps > 0
    assert engine.stats.batched_admissions >= 1


def test_legacy_modes_still_serve():
    """The baseline flags (lock across compute, per-request admission,
    polling dispatch) must keep producing correct tokens -- the serving
    benchmark records them as its pre-change reference."""
    model, params, kernel, engine = _stub_engine(
        max_batch=2, overlap_decode=False, batched_admission=False,
        kernel_kw={"dispatch": "polling"})
    kernel.start()
    engine.start()
    p = np.arange(1, 6, dtype=np.int32)
    r = engine.submit(Request(prompt=p, max_new_tokens=5))
    assert r.done_event.wait(timeout=30) and r.ok
    engine.stop()
    kernel.stop()
    assert r.tokens == _direct_greedy(model, params, p, 5)


def test_decode_snapshot_invalidated_by_concurrent_publish():
    """If the generation counter moves between snapshot and merge, the
    decode step must be discarded (not committed over the newer rows) and
    retried -- tokens stay correct and the discard is counted."""
    model, params, kernel, engine = _stub_engine(max_batch=2)
    orig = engine._decode
    fired = []

    def bump_after_decode(prms, caches, toks, pos):
        out = orig(prms, caches, toks, pos)
        if not fired:
            fired.append(1)
            with engine._lock:          # simulate a concurrent row publish
                engine._gen += 1
        return out

    engine._decode = bump_after_decode
    kernel.start()
    engine.start()
    p = np.arange(1, 5, dtype=np.int32)
    r = engine.submit(Request(prompt=p, max_new_tokens=5))
    assert r.done_event.wait(timeout=30) and r.ok
    engine.stop()
    kernel.stop()
    assert engine.stats.decode_invalidations == 1
    assert r.tokens == _direct_greedy(model, params, p, 5)


def test_bulk_prefill_vs_decode_under_slot_exhaustion():
    """More bulk requests than cache slots: prefill jobs yield until decode
    frees a slot; everyone completes and the pool drains back to full."""
    model, params, kernel, engine = _stub_engine(max_batch=2)
    kernel.start()
    engine.start()
    reqs = [engine.submit(Request(prompt=np.arange(1, 4, dtype=np.int32),
                                  tier="background", max_new_tokens=4))
            for _ in range(5)]
    for r in reqs:
        assert r.done_event.wait(timeout=30), "bulk request starved"
        assert r.ok, r.error
    engine.stop()
    kernel.stop()
    assert sorted(engine.pool.free) == [0, 1]
    assert engine.stats.bulk_prefills == 5
    expect = _direct_greedy(model, params, np.arange(1, 4, dtype=np.int32), 4)
    for r in reqs:
        assert r.tokens == expect


def test_stop_drain_fails_inflight_bulk():
    """A background submit() whose prefill has not landed a slot used to be
    invisible to stop(drain=True): its done_event waiter hung until
    deadline.  It must now fail with error='shutdown' immediately."""
    model, params, kernel, engine = _stub_engine(max_batch=1, max_len=4096)
    kernel.start()
    engine.start()
    # occupy the only slot with a request that cannot finish soon
    blocker = engine.submit(Request(prompt=np.arange(1, 4, dtype=np.int32),
                                    max_new_tokens=100_000))
    assert _wait_for(lambda: len(engine.active) == 1)
    bulk = engine.submit(Request(prompt=np.arange(1, 4, dtype=np.int32),
                                 tier="background", max_new_tokens=4))
    assert _wait_for(lambda: bulk.rid in engine._inflight_bulk)
    engine.stop()
    assert bulk.done_event.wait(timeout=5), "in-flight bulk leaked at drain"
    assert bulk.error == "shutdown" and not bulk.ok
    assert blocker.done_event.wait(timeout=5)
    assert blocker.error == "shutdown"
    assert _wait_for(lambda: sorted(engine.pool.free) == [0])
    kernel.stop()


def test_stop_drain_races_midflight_bulk_prefill():
    """Drain while a bulk prefill is mid-compute with a slot reserved: the
    merge step must observe the failure, skip activation and hand the slot
    back (fail-then-merge leaks the slot otherwise)."""

    class SlowPrefill(TinyStubModel):
        def prefill(self, params, batch, smax):
            time.sleep(0.3)              # hold the reserved slot a while
            return super().prefill(params, batch, smax)

    model = SlowPrefill()
    params = model.init_params(0)
    kernel = LiveKernel(2, make_policy("ufs"))
    engine = InferenceEngine(model, params, kernel, max_batch=1, max_len=64)
    kernel.start()
    engine.start()
    bulk = engine.submit(Request(prompt=np.arange(1, 4, dtype=np.int32),
                                 tier="background", max_new_tokens=4))
    # wait until the prefill job has reserved the slot (pool empty)
    assert _wait_for(lambda: not engine.pool.free, timeout=5)
    engine.stop()                        # drain while prefill is sleeping
    assert bulk.done_event.wait(timeout=5)
    assert bulk.error == "shutdown"
    assert _wait_for(lambda: sorted(engine.pool.free) == [0]), \
        "reserved slot leaked when drain raced the bulk merge"
    assert not engine.active
    kernel.stop()


def test_deadline_expires_inflight_bulk():
    """Deadline expiry must reach bulk requests still waiting for a slot
    (they are in no queue the old expire scan could see)."""
    model, params, kernel, engine = _stub_engine(max_batch=1, max_len=4096)
    kernel.start()
    engine.start()
    blocker = engine.submit(Request(prompt=np.arange(1, 4, dtype=np.int32),
                                    max_new_tokens=100_000))
    assert _wait_for(lambda: len(engine.active) == 1)
    bulk = engine.submit(Request(prompt=np.arange(1, 4, dtype=np.int32),
                                 tier="background", deadline_s=0.2,
                                 max_new_tokens=4))
    assert bulk.done_event.wait(timeout=10), "expired bulk request leaked"
    assert bulk.error == "deadline"
    engine.stop()
    kernel.stop()
    assert blocker.done_event.wait(timeout=5)   # shut down (or finished)


# ------------------------------------------------------- executor internals
def test_wait_job_settle_event_driven():
    """wait_job_settle returns as soon as the job parks, without polling."""
    kernel = LiveKernel(1, make_policy("ufs"))
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    n = {"chunks": 0}

    def chunk(budget):
        n["chunks"] += 1
        time.sleep(0.005)
        return "yield" if n["chunks"] < 3 else "blocked"

    job = LiveJob(ts, chunk, name="settle-me")
    kernel.start()
    kernel.wake(job)
    t0 = time.monotonic()
    state = kernel.executor.wait_job_settle(job, timeout=5.0)
    assert state == "blocked"
    assert time.monotonic() - t0 < 2.0
    assert job.state == JobState.BLOCKED
    kernel.stop()


def test_executor_reaps_threads_and_prunes_timers():
    kernel = LiveKernel(1, make_policy("ufs"))
    ex = kernel.executor
    kernel.start()
    fired = []
    ex.defer(0.01, lambda: fired.append(1))
    assert _wait_for(lambda: fired and not ex._timers), \
        "fired timer must self-prune from _timers"
    kernel.add_slot()
    assert len([t for t in ex._threads if t.is_alive()]) == 2
    kernel.stop()
    # stop joins + reaps; a later slot_added on a stopped executor must not
    # resurrect dead threads in the list
    assert all(not t.is_alive() for t in ex._threads) or not ex._threads
    kernel2 = LiveKernel(1, make_policy("ufs"))
    ex2 = kernel2.executor
    kernel2.start()
    for _ in range(3):
        kernel2.add_slot()
    alive = sum(t.is_alive() for t in ex2._threads)
    assert len(ex2._threads) == alive == 4, "dead threads accumulated"
    kernel2.stop()


def test_event_dispatch_parks_and_unparks():
    """Idle workers park on their per-slot event and are woken by targeted
    kicks; the park/unpark pair is traced and the stream stays valid."""
    tracer = SchedTracer(capacity=4096)
    kernel = LiveKernel(2, make_policy("ufs"), tracer=tracer)
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    kernel.start()
    time.sleep(0.1)                      # both workers park
    job = LiveJob(ts, lambda b: "done", name="one-shot")
    kernel.wake(job)
    assert _wait_for(lambda: job.state == JobState.EXITED)
    kernel.stop()
    events = tracer.events
    kinds = {e.kind for e in events}
    assert "park" in kinds and "unpark" in kinds
    validate_events(events)
    # the wakeup-delay analysis sees the wake -> start_job edge
    delays = wakeup_delays(events)
    assert delays and all(d >= 0 for ds in delays.values() for d in ds)


def test_idle_event_workers_do_not_spin():
    """Parked workers must stay parked while the kernel is idle: the
    guard-exit wake-scan only fires after an enqueue, so an idle fleet
    emits no unpark churn."""
    tracer = SchedTracer(capacity=4096)
    kernel = LiveKernel(2, make_policy("ufs"), tracer=tracer)
    kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    kernel.start()
    time.sleep(0.2)                      # settle: both park once
    before = sum(1 for e in tracer.events if e.kind == "unpark")
    time.sleep(0.3)                      # idle window
    after = sum(1 for e in tracer.events if e.kind == "unpark")
    kernel.stop()
    spins = after - before
    assert spins == 0, f"idle workers unparked {spins} times"


def test_queued_count_sees_policy_private_queues():
    """RT's global fair runqueue is policy-private; queued_count must
    include it or event dispatch under-wakes."""
    kernel = LiveKernel(1, make_policy("fifo"))   # never started: jobs queue
    ts = kernel.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = kernel.create_group("bg", Tier.BACKGROUND, 100)
    kernel.wake(LiveJob(ts, lambda b: "done", name="rt1"))
    kernel.wake(LiveJob(bg, lambda b: "done", name="fair1"))
    kernel.wake(LiveJob(bg, lambda b: "done", name="fair2"))
    with kernel.executor.guard():
        assert kernel.policy.queued_count() == 3
    kernel.stop()
