"""B5 regression: the explicit shard_map GQA mixer is numerically identical
to the reference attention path (full and sliding-window), on a real
multi-device mesh (subprocess with 8 host devices)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_shardmap_gqa_matches_reference():
    code = """
import dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.models import attention as A
from repro.distributed.shardmap_attention import make_shardmap_gqa

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(get_arch("llama3.2-1b").reduced(), qkv_bias=False)
key = jax.random.PRNGKey(0)
p = A.gqa_init(key, cfg)
x = jax.random.normal(key, (8, 32, cfg.d_model)) * 0.5
pos = jnp.arange(32)[None, :]
fwd = make_shardmap_gqa(mesh, cfg)
for window in (0, 8):
    y_ref = A.gqa_forward(cfg, p, x, pos, window=window)
    y_sm = fwd(p, x, pos, window)
    err = float(jnp.max(jnp.abs(y_sm - y_ref)))
    assert err < 1e-4, (window, err)
print("SHARDMAP-GQA-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDMAP-GQA-OK" in out.stdout


def test_expand_kv_weight_layout():
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.shardmap_attention import expand_kv_weight
    d, kh, hd, g = 4, 2, 3, 2
    w = jnp.arange(d * kh * hd, dtype=jnp.float32).reshape(d, kh * hd)
    e = expand_kv_weight(w, kh, g)
    assert e.shape == (d, kh * g * hd)
    # head i's q-group copies both equal the original kv head i
    w3 = np.asarray(w).reshape(d, kh, hd)
    e4 = np.asarray(e).reshape(d, kh, g, hd)
    for i in range(kh):
        for j in range(g):
            assert np.array_equal(e4[:, i, j], w3[:, i])
