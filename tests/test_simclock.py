"""Unit tests for the cancellable discrete-event clock."""
from repro.core import Job, SchedKernel, Tier, make_policy
from repro.core.kernel import SimClock
from repro.core.workloads import bound_worker, bursty_worker


def test_ordering_and_processed_count():
    clock = SimClock()
    fired = []
    clock.at(1.0, lambda: fired.append("a"))
    clock.at(1.0, lambda: fired.append("b"))   # same t: schedule order wins
    clock.at(0.5, lambda: fired.append("c"))
    clock.run_until(2.0)
    assert fired == ["c", "a", "b"]
    assert clock.processed == 3
    assert clock.now == 2.0


def test_cancel_prevents_execution():
    clock = SimClock()
    fired = []
    ev = clock.after(1.0, lambda: fired.append("x"))
    clock.after(2.0, lambda: fired.append("y"))
    assert clock.cancel(ev) is True
    assert clock.cancel(ev) is False           # second cancel is a no-op
    clock.run_until(3.0)
    assert fired == ["y"]
    assert clock.processed == 1


def test_cancel_after_execution_is_noop():
    clock = SimClock()
    ev = clock.after(0.5, lambda: None)
    clock.run_until(1.0)
    assert clock.cancel(ev) is False
    assert len(clock) == 0 and clock.empty()


def test_event_cancelling_itself_from_callback():
    """A callback cancelling its own (already-popped) handle must not
    corrupt the dead-cell accounting."""
    clock = SimClock()
    handles = []
    clock.after(1.0, lambda: clock.cancel(handles[0]))
    handles.append(clock._heap[0])
    clock.after(2.0, lambda: None)
    clock.run_until(3.0)
    assert clock.processed == 2
    assert len(clock) == 0 and clock.empty()


def test_past_events_clamp_to_now():
    clock = SimClock()
    fired = []
    clock.run_until(5.0)
    clock.at(1.0, lambda: fired.append(clock.now))
    clock.run_until(6.0)
    assert fired == [5.0]                      # never travels back in time


def test_compaction_bounds_heap_size():
    clock = SimClock()
    evs = [clock.after(10.0 + i, lambda: None) for i in range(1000)]
    for ev in evs[:900]:
        clock.cancel(ev)
    assert len(clock) == 100
    # Lazy deletion plus compaction: the raw heap stays near the live count.
    assert clock.heap_size < 300
    clock.run_until(2000.0)
    assert clock.processed == 100


def test_live_len_and_empty_track_cancellation():
    clock = SimClock()
    a = clock.after(1.0, lambda: None)
    b = clock.after(2.0, lambda: None)
    assert len(clock) == 2 and not clock.empty()
    clock.cancel(a)
    assert len(clock) == 1
    clock.cancel(b)
    assert len(clock) == 0 and clock.empty()


def test_sim_run_leaves_no_stale_run_end_events():
    """Preempt/slice churn used to leave one dead closure per stop in the
    heap; with cancellation the heap stays bounded by live timers."""
    k = SchedKernel(2, make_policy("ufs"), seed=1)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    for i in range(4):
        k.add_job(Job(ts, behavior=bursty_worker(i), name=f"t{i}",
                      kind="bursty"))
    for i in range(8):
        k.add_job(Job(bg, behavior=bound_worker(100 + i, query_cpu=0.02),
                      name=f"b{i}", kind="bound"))
    k.run(2.0)
    # Live events: at most one run-end per slot plus one block timer per
    # sleeping job -- nowhere near the thousands of stops that occurred.
    assert len(k.clock) <= 2 + 12
    assert k.clock.heap_size <= 2 * (2 + 12) + 64
    assert k.metrics.preemptions + k.metrics.dispatches > 0
