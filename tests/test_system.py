"""End-to-end behaviour tests: the paper's headline claims, reproduced in
sim mode (fast, deterministic). Quantitative bands follow Figures 1/6 and
Table 3; tolerances are loose enough for short windows."""
import pytest

from repro.core.experiment import scenario

pytestmark = pytest.mark.slow    # shared 8 s sim scenarios per scheduler

DUR, WARM = 8.0, 3.0


@pytest.fixture(scope="module")
def results():
    out = {}
    for mix in ("solo", "minmax", "5050"):
        for pol in ("ufs", "vdf", "fifo", "rr"):
            out[(mix, pol)] = scenario(pol, mix, n_slots=8, n=8,
                                       duration=DUR, warmup=WARM)
    return out


def test_solo_equal_across_schedulers(results):
    thr = [results[("solo", p)].thr("ts") for p in ("ufs", "vdf", "fifo", "rr")]
    assert max(thr) / min(thr) < 1.05


def test_solo_latency_calibration(results):
    ls = results[("solo", "ufs")].lat("ts")
    # Table 3 SOLO: mean ~3.06 ms, p95 ~5.8 ms
    assert 2.5e-3 < ls["mean"] < 3.6e-3
    assert 4.5e-3 < ls["p95"] < 7.5e-3


def test_minmax_ufs_matches_solo(results):
    # UFS keeps time-sensitive throughput at SOLO level under MIN:MAX
    assert results[("minmax", "ufs")].thr("ts") > 0.97 * results[("solo", "ufs")].thr("ts")


def test_minmax_vdf_degrades_2x(results):
    """EEVDF loses ~50% TS throughput at MIN:MAX (paper: 'reducing their
    throughput by 50%'); UFS delivers ~2x EEVDF."""
    ufs = results[("minmax", "ufs")].thr("ts")
    vdf = results[("minmax", "vdf")].thr("ts")
    assert ufs > 1.5 * vdf


def test_minmax_latency_tail(results):
    # Table 3 MIN:MAX: EEVDF mean ~2x UFS, p95 ~2.2x UFS
    u, v = results[("minmax", "ufs")].lat("ts"), results[("minmax", "vdf")].lat("ts")
    assert v["mean"] > 1.6 * u["mean"]
    assert v["p95"] > 1.7 * u["p95"]


def test_minmax_vdf_lets_background_overrun(results):
    # 'they allow background CPU-bound tasks to reach unexpectedly high throughput'
    assert results[("minmax", "vdf")].thr("bg") > 1.4 * results[("minmax", "ufs")].thr("bg")


def test_5050_fifo_collapses(results):
    # 'the throughput collapses, even reaching zero in one case' (FIFO)
    assert results[("5050", "fifo")].thr("ts") == 0.0


def test_5050_rr_deteriorates(results):
    # Table 3 50:50: RR latencies 'completely deteriorated'
    rr = results[("5050", "rr")].lat("ts")
    ufs = results[("5050", "ufs")].lat("ts")
    assert rr["mean"] > 10 * ufs["mean"]


def test_5050_ufs_balances(results):
    """UFS keeps both classes alive at 50:50 (paper: ~75% bursty / ~50%
    bound of SOLO)."""
    solo_ts = results[("solo", "ufs")].thr("ts")
    r = results[("5050", "ufs")]
    assert r.thr("ts") > 0.5 * solo_ts
    assert r.thr("bg") > 0.35 * 8.0          # bound solo ~= 8 q/s on 8 slots
    # and better than VDF for the bursty class
    assert r.thr("ts") > 1.2 * results[("5050", "vdf")].thr("ts")


def test_fig2_placement_skew(results):
    """EEVDF stacks bursty tasks on few slots (Figure 2); UFS spreads."""
    vdf_skew = results[("minmax", "vdf")].metrics.slot_skew("bursty", 8)
    ufs_skew = results[("minmax", "ufs")].metrics.slot_skew("bursty", 8)
    assert vdf_skew > 1.25
    assert ufs_skew < 1.1
