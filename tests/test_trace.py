"""Trace-plane tests: schema invariants, Figure-2 cross-check against
Metrics, determinism, inversion detection, the Chrome exporter, and the
unified build_kernel / KernelReport surface (ISSUE 7 acceptance criteria).

The load-bearing property is that the trace is a *second, independent*
accounting path: per-slot busy time reconstructed from start_job/stop_job
events must agree with the charge-time accounting in ``Metrics`` --
including window clipping -- or one of the two is lying.
"""
import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core import (Job, KernelReport, SchedKernel, SchedTracer, Tier,
                        build_kernel, detect_inversions, slot_busy_from_trace,
                        to_chrome_trace, validate_chrome_trace,
                        validate_events, wakeup_delays, write_chrome_trace)
from repro.core.experiment import run_mix
from repro.core.live import LiveJob, LiveKernel
from repro.core.metrics import Metrics
from repro.core.task import JobState
from repro.core.trace import TraceSchemaError
from repro.core.ufs import UFSPolicy
from repro.core.workloads import burner, holder, waiter

WARMUP, DUR = 0.3, 1.0


def _traced_mix(**kw):
    tr = SchedTracer()
    r = run_mix("ufs", n_slots=2, n_bursty=2, n_bound=2,
                duration=DUR, warmup=WARMUP, tracer=tr, **kw)
    return tr, r


# ---------------------------------------------------------------------------
# Schema invariants
# ---------------------------------------------------------------------------

def test_mixed_sim_trace_passes_schema():
    """A full mixed run satisfies every schema invariant: only known kinds,
    monotone-safe timestamps, every start_job closed by a stop_job before
    the next start on that slot (jobs still on-slot at the horizon are the
    only tolerated open runs)."""
    tr, _ = _traced_mix()
    evs = tr.events
    assert tr.dropped == 0, "ring must not wrap in this config"
    counts = validate_events(evs, balanced=False)
    for kind in ("wake", "enqueue", "dispatch", "start_job", "stop_job",
                 "preempt_slot", "kick"):
        assert counts.get(kind, 0) > 0, f"mixed run must emit {kind}"
    # At most one open run per slot at the horizon.
    assert 0 <= counts["start_job"] - counts["stop_job"] <= 2


def test_validate_events_catches_violations():
    tr = SchedTracer()

    class J:
        jid, name, kind = 7, "j", "bursty"
        group = type("G", (), {"name": "ts"})

    tr.emit("start_job", 1.0, slot=0, job=J())
    with pytest.raises(TraceSchemaError, match="still running"):
        tr.emit("start_job", 2.0, slot=0, job=J())
        validate_events(tr.events)
    with pytest.raises(TraceSchemaError, match="unbalanced"):
        validate_events(tr.events[:1], balanced=True)
    validate_events(tr.events[:1], balanced=False)   # tolerated when asked

    tr2 = SchedTracer()
    tr2.emit("unboost", 1.0, job=J())
    with pytest.raises(TraceSchemaError, match="without boost"):
        validate_events(tr2.events)

    tr3 = SchedTracer()
    tr3.emit("stop_job", 1.0, slot=3, job=J())
    with pytest.raises(TraceSchemaError, match="idle slot"):
        validate_events(tr3.events)


def test_tracer_ring_bounds_and_kind_filter():
    tr = SchedTracer(capacity=4)
    for i in range(10):
        tr.emit("kick", float(i), slot=0)
    assert len(tr.events) == 4 and tr.emitted == 10 and tr.dropped == 6
    assert [e.t for e in tr.events] == [6.0, 7.0, 8.0, 9.0]

    trf = SchedTracer(kinds={"kick"})
    trf.emit("kick", 0.0, slot=0)
    trf.emit("wake", 0.1)
    assert [e.kind for e in trf.events] == ["kick"]

    with pytest.raises(ValueError):
        SchedTracer(kinds={"not_a_kind"})
    with pytest.raises(ValueError):
        SchedTracer(capacity=0)


# ---------------------------------------------------------------------------
# Figure-2 cross-check: trace-derived busy timeline vs Metrics
# ---------------------------------------------------------------------------

def test_trace_busy_matches_metrics_slot_utilization():
    """The trace-derived per-slot busy timeline must agree with the
    charge-time accounting in Metrics, per kind and per slot, including the
    warmup/horizon window clipping -- both paths see the same run edges, so
    agreement is exact up to float rounding."""
    tr, r = _traced_mix()
    end = WARMUP + DUR
    for kind in ("bursty", "bound"):
        from_trace = slot_busy_from_trace(tr.events, r.n_slots, kind=kind,
                                          window=(WARMUP, end), end=end)
        from_metrics = r.metrics.slot_utilization(kind, r.n_slots)
        assert from_trace == pytest.approx(from_metrics, abs=1e-9), kind
        assert sum(from_trace) > 0.0, f"no {kind} busy time recorded"


def test_wakeup_delays_match_metrics_convention():
    tr, r = _traced_mix()
    d = wakeup_delays(tr.events)
    assert "ts" in d and len(d["ts"]) > 0
    assert all(x >= 0.0 for x in d["ts"])
    # Metrics only records wakeups inside the window; the trace sees all of
    # them, so the trace count dominates.
    assert len(d["ts"]) >= len(r.metrics.wakeup_latency["ts"])


# ---------------------------------------------------------------------------
# Determinism: fixed seed => byte-stable export
# ---------------------------------------------------------------------------

def test_sim_trace_byte_stable(tmp_path):
    """Two identical seeded sim runs export byte-identical Chrome traces.
    Each run goes in a fresh interpreter: job ids come from a process-global
    counter, so byte stability is a property of an invocation, not of
    repeated runs inside one process."""
    script = ("from repro.core import SchedTracer, write_chrome_trace\n"
              "from repro.core.experiment import run_mix\n"
              "import sys\n"
              "tr = SchedTracer()\n"
              "run_mix('ufs', n_slots=2, n_bursty=2, n_bound=2,\n"
              "        duration=0.5, warmup=0.1, tracer=tr, seed=13)\n"
              "n = write_chrome_trace(tr.events, sys.argv[1], end=0.6)\n"
              "assert n > 0\n")
    paths = [tmp_path / "a.json", tmp_path / "b.json"]
    env = dict(os.environ, PYTHONPATH="src")
    for p in paths:
        subprocess.run([sys.executable, "-c", script, str(p)], check=True,
                       env=env, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert paths[0].read_bytes() == paths[1].read_bytes()


# ---------------------------------------------------------------------------
# Priority inversion: the boost shows up as a detectable span
# ---------------------------------------------------------------------------

def test_inversion_detected_with_resolution():
    # Full 40 s horizon at slice granularity emits ~80k events; size the
    # ring so the early boost/unboost pair survives to the end.
    tr = SchedTracer(capacity=1 << 18)
    k = build_kernel("sim", policy="ufs", hints_enabled=True, tracer=tr)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    lock = k.create_lock("spin")
    jobs = [Job(bg, behavior=holder(lock, compute=1.0), name="holder"),
            Job(ts, behavior=waiter(lock), name="waiter"),
            Job(ts, behavior=burner(total=30.0), name="burner")]
    for j in jobs:
        j.pinned_slot = 0
        k.add_job(j)
    k.run(40.0)
    validate_events(tr.events, balanced=False)

    inv = detect_inversions(tr.events)
    resolved = [i for i in inv if i["resolution"] is not None]
    assert resolved, "hinted run must produce at least one resolved inversion"
    assert all(i["resolution"] > 0.0 for i in resolved)
    assert resolved[0]["job"] == "holder"
    assert resolved[0]["boost_group"] == "ts"

    s = tr.summary()
    assert s.inversions == len(inv)
    assert s.inversions_resolved == len(resolved)
    assert s.max_boost_resolution == max(i["resolution"] for i in resolved)
    # Lock identity is in the trace: the wait names the holder.
    waits = [e for e in tr.events if e.kind == "lock_wait"]
    assert any(e.args.get("holder") == "holder" for e in waits)


# ---------------------------------------------------------------------------
# Chrome export (acceptance: sim AND live both export valid trace JSON)
# ---------------------------------------------------------------------------

def _live_traced(dur=0.5):
    tr = SchedTracer()
    k = build_kernel("live", policy="ufs", n_slots=1, tracer=tr)
    ts = k.create_group("ts", Tier.TIME_SENSITIVE, 10_000)
    bg = k.create_group("bg", Tier.BACKGROUND, 1)
    tsj = LiveJob(ts, lambda b: (time.sleep(0.002), "blocked")[1],
                  name="ts0", kind="bursty")
    stop = threading.Event()

    def waker():
        while not stop.is_set():
            time.sleep(0.005)
            if tsj.state == JobState.BLOCKED:
                k.wake(tsj)

    k.start()
    k.wake(tsj)
    k.wake(LiveJob(bg, lambda b: (time.sleep(0.002), "yield")[1],
                   name="bg0", kind="bound"))
    wt = threading.Thread(target=waker, daemon=True)
    wt.start()
    time.sleep(dur)
    stop.set()
    wt.join()
    k.stop()
    return tr, k


def test_chrome_export_valid_sim_and_live(tmp_path):
    sim_tr, _ = _traced_mix()
    live_tr, live_k = _live_traced()
    for name, tr, end in (("sim", sim_tr, WARMUP + DUR),
                          ("live", live_tr, live_k.now)):
        p = tmp_path / f"{name}.json"
        n = write_chrome_trace(tr.events, str(p), end=end)
        doc = json.loads(p.read_text())
        assert validate_chrome_trace(doc) == n
        phases = {ev["ph"] for ev in doc["traceEvents"]}
        assert {"X", "M"} <= phases, name
        pids = {ev["pid"] for ev in doc["traceEvents"]}
        assert {1, 2} <= pids, f"{name}: needs slot and group tracks"


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(TraceSchemaError):
        validate_chrome_trace({"traceEvents": []})
    # An empty stream still exports the three process-name records.
    assert validate_chrome_trace(to_chrome_trace([])) == 3
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 0,
                            "ts": 0}]}             # X without dur
    with pytest.raises(TraceSchemaError, match="dur"):
        validate_chrome_trace(bad)


# ---------------------------------------------------------------------------
# Sim/live parity on the TraceSummary
# ---------------------------------------------------------------------------

def test_sim_live_trace_summary_parity():
    """Both backends drive the same SchedCore, so the set of lifecycle kinds
    they emit must match (absolute counts are clock-dependent and never
    compared).  Lock kinds are excluded: the two workload shapes here take
    no locks, so they should not appear at all."""
    sim_tr, _ = _traced_mix()
    live_tr, _ = _live_traced()
    sim_s, live_s = sim_tr.summary(), live_tr.summary()
    diff = sim_s.diff(live_s)
    for k in ("lock_wait", "lock_acquire", "lock_release"):
        diff.pop(k, None)
    assert diff == {}, f"backends emit different lifecycle kinds: {diff}"
    for s in (sim_s, live_s):
        for kind in ("wake", "enqueue", "start_job", "stop_job",
                     "preempt_slot"):
            assert s.counts.get(kind, 0) > 0
    rt = json.loads(sim_s.to_json())
    assert rt["events"] == sim_s.events


# ---------------------------------------------------------------------------
# build_kernel / KernelReport / deprecation shims
# ---------------------------------------------------------------------------

def test_build_kernel_modes():
    k = build_kernel("sim", policy="ufs", n_slots=3, seed=5)
    assert isinstance(k, SchedKernel) and len(k.slots) == 3
    assert k.tracer is None
    kt = build_kernel("sim", policy="vdf", trace=True)
    assert isinstance(kt.tracer, SchedTracer)
    mine = SchedTracer(capacity=8)
    assert build_kernel("sim", tracer=mine, trace=True).tracer is mine
    kl = build_kernel("live", policy="ufs")
    assert isinstance(kl, LiveKernel)
    assert isinstance(build_kernel("sim", policy=UFSPolicy()), SchedKernel)
    with pytest.raises(ValueError, match="unknown mode"):
        build_kernel("gpu")
    with pytest.raises(ValueError, match="unknown policy"):
        build_kernel("sim", policy="nope")


def test_kernel_report_roundtrip():
    tr, r = _traced_mix()
    k = build_kernel("sim", policy="ufs", n_slots=2, tracer=tr)
    # Reuse the finished run's metrics for the report surface.
    k.metrics = r.metrics
    rep = KernelReport.from_kernel(k)
    assert rep.mode == "sim" and rep.n_slots == 2
    d = json.loads(rep.to_json())          # strict JSON: no NaN/Inf allowed
    assert d["metrics"]["groups"]["ts"]["completed"] > 0
    assert d["trace"]["events"] == len(tr.events)
    txt = rep.pretty()
    assert "group ts" in txt and "trace:" in txt


def test_sched_kernel_legacy_positionals_warn_and_map():
    m = Metrics()
    with pytest.warns(DeprecationWarning):
        k = SchedKernel(1, UFSPolicy(), None, m, 0.25, False, 9)
    assert k.metrics is m
    assert k.kick_latency == 0.25
    assert k.hints_enabled is False
    with pytest.raises(TypeError, match="positional"):
        SchedKernel(1, UFSPolicy(), None, None, 0.0, True, 0, "extra")


def test_live_kernel_legacy_positionals_warn_and_map():
    with pytest.warns(DeprecationWarning):
        k = LiveKernel(1, UFSPolicy(), None, False, 0.125)
    assert k.hints_enabled is False
    assert k.kick_latency == 0.125
    # The unified keyword form accepts the shared signature silently.
    m = Metrics()
    k2 = LiveKernel(1, UFSPolicy(), metrics=m, seed=3, tracer=SchedTracer())
    assert k2.metrics is m and k2.tracer is not None


def test_mix_result_summary_consolidation():
    _, r = _traced_mix()
    s = r.summary()
    assert s is r.summary()                        # computed once, cached
    assert r.thr("ts") == s["groups"]["ts"]["throughput"]
    assert r.lat("ts")["p95"] == s["groups"]["ts"]["latency"]["p95"]
    assert r.thr("missing") == 0.0
    assert s["slots"]["n"] == r.n_slots
    assert s["slots"]["busy_by_kind"]["bursty"] == \
        r.metrics.slot_utilization("bursty", r.n_slots)
