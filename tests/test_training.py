"""Training substrate: optimizer math, grad accumulation equivalence,
gradient compression (error feedback), loss-goes-down integration,
checkpoint fault tolerance, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.pipeline import SyntheticTokens, TokenFile, batches
from repro.models.transformer import Model
from repro.training import grad_compress
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig, apply_updates, init_state, lr_at
from repro.training.trainer import TrainConfig, init_state as tstate, make_train_step

KEY = jax.random.PRNGKey(0)


def tiny_model():
    cfg = get_arch("llama3.2-1b").reduced()
    return Model(cfg), cfg


# ---------------------------------------------------------------- optimizer
def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1, rel=1e-3)


def test_adamw_step_moves_toward_gradient():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    st = init_state(cfg, params)
    newp, st, m = apply_updates(cfg, params, grads, st)
    assert float(jnp.max(newp["w"])) < 1.0
    assert int(st["step"]) == 1
    assert m["grad_norm"] > 0


def test_bf16_optimizer_state_dtype():
    cfg = OptimizerConfig(state_dtype="bfloat16")
    st = init_state(cfg, {"w": jnp.ones((8,))})
    assert st["m"]["w"].dtype == jnp.bfloat16


@pytest.mark.slow
def test_grad_accum_equivalence():
    """accum=2 over a batch == accum=1 on the same batch (linear loss avg)."""
    model, cfg = tiny_model()
    params = model.init_params(KEY)
    toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0, clip_norm=0.0)
    s1 = {"params": params, "opt": init_state(ocfg, params)}
    s2 = {"params": params, "opt": init_state(ocfg, params)}
    step1 = make_train_step(model, TrainConfig(grad_accum=1, opt=ocfg))
    step2 = make_train_step(model, TrainConfig(grad_accum=2, opt=ocfg))
    n1, m1 = step1(s1, batch)
    n2, m2 = step2(s2, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     n1["params"], n2["params"])
    assert max(jax.tree.leaves(d)) < 2e-3
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3


def test_loss_decreases_on_tiny_model():
    model, cfg = tiny_model()
    tcfg = TrainConfig(opt=OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=50))
    state = tstate(model, tcfg, KEY)
    step = jax.jit(make_train_step(model, tcfg))
    src = SyntheticTokens(cfg.vocab_size, seed=1)
    losses = []
    batch = src.batch(0, 0, 8, 32)           # overfit one batch
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    for i in range(20):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8


# ------------------------------------------------------------- compression
def test_compression_error_feedback_unbiased():
    g = jax.random.normal(KEY, (512,))
    err = grad_compress.init_error_state(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        out, err = grad_compress.compress_decompress(g, err)
        acc = acc + out
    # time-averaged compressed gradient converges to the true gradient
    assert float(jnp.max(jnp.abs(acc / 50 - g))) < 0.02


def test_compressed_psum_single_axis():
    import jax.experimental.shard_map as shm
    mesh = jax.make_mesh((1,), ("pod",))
    x = jax.random.normal(KEY, (64,))
    f = shm.shard_map(lambda a: grad_compress.compressed_psum(a, "pod"),
                      mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                      out_specs=jax.sharding.PartitionSpec())
    y = f(x)
    assert float(jnp.max(jnp.abs(y - x))) < 0.05    # quantization error only


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree))
    assert mgr.all_steps() == [2, 3]          # keep_n GC
    restored = mgr.restore(3, tree)
    assert np.allclose(restored["a"], np.arange(6.0).reshape(2, 3) * 3)


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones((2,))}
    mgr.save(5, tree)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 5


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones((16,))}
    mgr.save(1, tree)
    d = os.path.join(str(tmp_path), "step_00000001")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, fn))
    arr[0] = 999.0
    np.save(os.path.join(d, fn), arr)
    with pytest.raises(IOError):
        mgr.restore(1, tree)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    tree = {"a": jnp.full((32,), 7.0)}
    mgr.save(4, tree)
    mgr.wait()
    assert mgr.latest_step() == 4
    got = mgr.restore(4, tree)
    assert np.allclose(got["a"], 7.0)


def test_resume_after_simulated_failure(tmp_path):
    """Train, checkpoint, 'crash', restore, continue: loss state matches."""
    model, cfg = tiny_model()
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=0))
    state = tstate(model, tcfg, KEY)
    step = jax.jit(make_train_step(model, tcfg))
    src = SyntheticTokens(cfg.vocab_size, seed=3)
    mgr = CheckpointManager(str(tmp_path))
    for i in range(3):
        b = {k: jnp.asarray(v) for k, v in src.batch(i, 0, 4, 16).items()}
        state, _ = step(state, b)
    mgr.save(3, state)
    ref_state = state
    for i in range(3, 5):
        b = {k: jnp.asarray(v) for k, v in src.batch(i, 0, 4, 16).items()}
        ref_state, _ = step(ref_state, b)
    # crash + restore
    like = jax.tree.map(lambda x: x, state)
    step_n, restored = mgr.restore_latest(like)
    assert step_n == 3
    for i in range(3, 5):
        b = {k: jnp.asarray(v) for k, v in src.batch(i, 0, 4, 16).items()}
        restored, _ = step(restored, b)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                           - b.astype(jnp.float32)))),
                        ref_state["params"], restored["params"])
    assert max(jax.tree.leaves(diff)) < 1e-5  # deterministic resume


# --------------------------------------------------------------- pipeline
def test_synthetic_determinism():
    src = SyntheticTokens(1000, seed=5)
    a = src.batch(3, 1, 4, 16)
    b = src.batch(3, 1, 4, 16)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = src.batch(3, 2, 4, 16)
    assert not np.array_equal(a["tokens"], c["tokens"])   # rank-sharded


def test_token_file_and_prefetch(tmp_path):
    path = os.path.join(str(tmp_path), "toks.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    tf = TokenFile(path, seed=1)
    it = batches(tf, steps=4, dp_rank=0, dp_size=2, batch=2, seq=32)
    got = list(it)
    assert len(got) == 4
    assert got[0]["tokens"].shape == (2, 32)
    assert np.array_equal(got[0]["labels"][:, 0], got[0]["tokens"][:, 1])
